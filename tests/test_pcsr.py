"""PCSR format invariants: unit + seeded property tests."""
import numpy as np
import pytest

from repro.core.pcsr import (SpMMConfig, build_pcsr, config_space,
                             pcsr_stats, split_granularity, transpose_csr)
from repro.core.sparse import CSRMatrix

from conftest import random_csr
from _propcheck import booleans, floats, integers, propcases, sampled_from


def _dense_from_pcsr(p):
    """Reconstruct the dense matrix a PCSR encodes (slot accounting)."""
    V, W, R = p.config.V, p.config.W, p.config.R
    A = np.zeros((p.n_blocks * R, p.n_cols), np.float32)
    K = p.K
    for c in range(p.num_chunks):
        for k in range(K):
            i = c * K + k
            col = p.colidx[i]
            base = p.trow[c] * R + p.lrow[i] * V
            for v in range(V):
                A[base + v, col] += p.vals[c, v, k]
    return A[:p.n_rows]


@pytest.mark.parametrize("V,S,W", [(1, False, 8), (2, False, 4),
                                   (1, True, 16), (2, True, 8)])
def test_pcsr_roundtrip(rng, V, S, W):
    csr, A = random_csr(rng, 77, 0.08)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 77, 77,
                   SpMMConfig(V=V, S=S, W=W))
    np.testing.assert_allclose(_dense_from_pcsr(p), A, atol=1e-6)


def test_slot_accounting(rng):
    csr, _ = random_csr(rng, 120, 0.05, skew=True)
    for cfg in config_space(64):
        p = build_pcsr(csr.indptr, csr.indices, csr.data, 120, 120, cfg)
        assert p.num_slots >= p.nnz_vec
        assert p.nnz_vec * cfg.V >= p.nnz
        assert 0 <= p.padding_ratio <= 1 - 1 / cfg.V + 1e-9
        assert p.split_ratio >= 1.0
        assert p.K % 8 == 0


def test_stats_match_build(rng):
    csr, _ = random_csr(rng, 200, 0.03, skew=True)
    for V, W in [(1, 8), (2, 8), (2, 16)]:
        st_ = pcsr_stats(csr.indptr, csr.indices, 200, 200, V, W)
        for S in (False, True):
            p = build_pcsr(csr.indptr, csr.indices, csr.data, 200, 200,
                           SpMMConfig(V=V, S=S, W=W))
            C, K, slots = st_.chunks_and_slots(S)
            assert C == p.num_chunks
            assert K == p.K
            assert slots == p.num_slots
        assert st_.nnz_vec == p.nnz_vec


def test_split_granularity_formula():
    # paper Eq.3 with sublane roundup
    assert split_granularity(100, 10) == 16   # mean 10 → round8 = 16
    assert split_granularity(8, 8) == 8
    assert split_granularity(0, 0) == 8


def test_transpose_involution(rng):
    csr, A = random_csr(rng, 50, 0.1)
    t = csr.transpose()
    np.testing.assert_allclose(t.to_dense(), A.T, atol=1e-6)
    np.testing.assert_allclose(t.transpose().to_dense(), A, atol=1e-6)


@pytest.mark.parametrize("case", propcases(
    25, n=integers(5, 60), density=floats(0.01, 0.4),
    v=sampled_from([1, 2]), s=booleans(),
    w=sampled_from([2, 8, 16]), seed=integers(0, 1000)), ids=str)
def test_pcsr_encodes_matrix_property(case):
    """Property: PCSR is a lossless encoding of A for every config."""
    rng = np.random.default_rng(case.seed)
    A = (rng.random((case.n, case.n)) < case.density) \
        * rng.standard_normal((case.n, case.n))
    A = A.astype(np.float32)
    csr = CSRMatrix.from_dense(A)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=case.w))
    np.testing.assert_allclose(_dense_from_pcsr(p), A, atol=1e-6)


def test_empty_matrix():
    csr = CSRMatrix(np.zeros(11, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.float32), 10, 10)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 10, 10, SpMMConfig())
    assert p.nnz == 0 and p.num_chunks >= 1
