"""Selective-scan Pallas kernel vs associative-scan oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan import selective_scan, selective_scan_ref
from _propcheck import integers, propcases, sampled_from


def _mk(rng, B, S, N, Di):
    # decays in (0,1), bounded inputs — the regime mamba produces
    dA = jnp.asarray(rng.uniform(0.2, 0.99, (B, S, N, Di)), jnp.float32)
    dBx = jnp.asarray(rng.standard_normal((B, S, N, Di)) * 0.1, jnp.float32)
    C = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    return dA, dBx, C


@pytest.mark.slow
@pytest.mark.parametrize("B,S,N,Di,chunk,tile", [
    (2, 64, 4, 128, 16, 128),
    (1, 100, 8, 200, 32, 128),     # padding on both S and Di
    (2, 256, 16, 64, 128, 64),
    (1, 33, 2, 130, 16, 128),
])
def test_selective_scan_matches_ref(B, S, N, Di, chunk, tile):
    rng = np.random.default_rng(0)
    dA, dBx, C = _mk(rng, B, S, N, Di)
    got = np.asarray(selective_scan(dA, dBx, C, chunk=chunk, tile=tile))
    ref = np.asarray(selective_scan_ref(dA, dBx, C))
    np.testing.assert_allclose(got, ref, atol=2e-4, rtol=2e-4)


def test_state_carries_across_chunks():
    """A single impulse at t=0 must still influence the LAST chunk."""
    B, S, N, Di = 1, 64, 2, 128
    dA = jnp.full((B, S, N, Di), 0.95, jnp.float32)
    dBx = jnp.zeros((B, S, N, Di), jnp.float32).at[:, 0].set(1.0)
    C = jnp.ones((B, S, N), jnp.float32)
    y = np.asarray(selective_scan(dA, dBx, C, chunk=16))
    expect_last = 2 * 0.95 ** (S - 1)          # N=2 summed
    np.testing.assert_allclose(y[0, -1, 0], expect_last, rtol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("case", propcases(
    10, S=integers(4, 70), N=sampled_from([2, 4, 8]),
    Di=sampled_from([32, 130]), seed=integers(0, 99)), ids=str)
def test_selective_scan_property(case):
    rng = np.random.default_rng(case.seed)
    dA, dBx, C = _mk(rng, 1, case.S, case.N, case.Di)
    got = np.asarray(selective_scan(dA, dBx, C, chunk=16, tile=128))
    ref = np.asarray(selective_scan_ref(dA, dBx, C))
    np.testing.assert_allclose(got, ref, atol=2e-4, rtol=2e-4)


@pytest.mark.slow
def test_mamba_branch_backends_agree():
    """hymba forward is identical whichever scan backend runs."""
    import jax
    from repro.configs import get_reduced
    from repro.models import lm
    from repro.models.common import set_perf_options, reset_perf_options

    cfg = get_reduced("hymba-1.5b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    try:
        reset_perf_options()
        a = lm.forward_hidden(params, cfg, batch, remat=False, chunk=32)
        set_perf_options(ssm_backend="pallas")
        b = lm.forward_hidden(params, cfg, batch, remat=False, chunk=32)
    finally:
        reset_perf_options()
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=5e-2, rtol=5e-2)
