"""Reordering (paper §4.4) + TPU cost model sanity."""
import numpy as np

from repro.core.cost_model import CostModel, kernel_cost
from repro.core.features import extract_features
from repro.core.pcsr import SpMMConfig, config_space, pcsr_stats
from repro.core.reorder import apply_reorder, degree_reorder, rabbit_reorder
from repro.data.graphs import clones, grid2d, rmat


def test_reorder_is_permutation():
    g = rmat(9, 6, seed=3)
    perm = rabbit_reorder(g)
    assert sorted(perm.tolist()) == list(range(g.n_rows))
    perm2 = degree_reorder(g)
    assert sorted(perm2.tolist()) == list(range(g.n_rows))


def test_reorder_preserves_spectrum():
    g = grid2d(12, seed=0)
    perm = rabbit_reorder(g)
    g2 = apply_reorder(g, perm)
    assert g2.nnz == g.nnz
    # degree multiset preserved
    assert sorted(np.diff(g2.indptr)) == sorted(np.diff(g.indptr))


def test_reorder_improves_locality_on_shuffled_clones():
    """The portfolio optimizes PR_2 (what V=2 blocking consumes)."""
    g = clones(2000, 10, seed=1, shuffle=True)
    pr_before = extract_features(g).as_dict()["pr_2"]
    g2 = apply_reorder(g, rabbit_reorder(g))
    pr_after = extract_features(g2).as_dict()["pr_2"]
    assert pr_after < pr_before - 0.02


def test_cost_model_prefers_balance_on_skew():
    skew = rmat(11, 8, seed=5)
    flat = grid2d(48, seed=5)
    for dim in (32, 128):
        b_skew, _ = CostModel(skew).best(dim, config_space(dim))
        b_flat, _ = CostModel(flat).best(dim, config_space(dim))
        assert b_skew.S is True
        assert b_flat.S is False


def test_cost_model_v2_wins_on_clones():
    g = clones(3000, 10, seed=2)
    best, _ = CostModel(g).best(64, config_space(64))
    assert best.V == 2


def test_kernel_cost_monotonic_in_dim():
    g = rmat(10, 6, seed=0)
    cm = CostModel(g)
    cfg = SpMMConfig(V=1, S=True, W=8)
    assert cm.time(256, cfg) > cm.time(64, cfg)
