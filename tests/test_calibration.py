"""Calibration subsystem: NNLS fitter, artifact round-trip, and the
rank-correlation / regret gates every speed claim now rides on.

The measured gates (``@pytest.mark.measured``) time the jit'd engine on
the pinned ``calibrate.gate_design`` subset — in tier-1 by default,
deselectable on loaded machines with ``pytest -m "not measured"``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.calibrate import (COLUMNS, GATE_DIMS, GATE_GRAPHS,
                                  GATE_REPS, CalibrationResult,
                                  CalibrationSample, breakdown_features,
                                  fit, fit_columns, gate_design, nnls,
                                  reference_coefficients, spearman)
from repro.core.cost_model import HBM_BW, CostModel
from repro.core.pcsr import config_space
from repro.data.graphs import corpus, er


# ------------------------------------------------------------- the fitter
def _log_uniform_design(rng, n=240, noise=0.02):
    """Well-conditioned synthetic design: independent log-uniform columns
    spanning each feature's realistic range.  (The real spmm design is
    structurally collinear — bytes_gather = steps·dblk·4 — so coefficient
    *recovery* is asserted here; rank quality on the real design is the
    measured gate below.)"""
    X = np.stack([
        np.ones(n),
        10 ** rng.uniform(3, 8, n),     # bytes
        10 ** rng.uniform(4, 9, n),     # flops
        10 ** rng.uniform(1, 6, n),     # steps
        10 ** rng.uniform(0, 4, n),     # chunk setups
    ], axis=1)
    true = np.array([2e-5, 1 / 80e9, 1 / 5e10, 3e-7, 1e-6])
    y = X @ true * (1.0 + noise * rng.standard_normal(n))
    return X, y, true


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fit_recovers_synthetic_constants(seed):
    """ISSUE acceptance: ≤10% relative error on every constant at 2%
    measurement noise."""
    X, y, true = _log_uniform_design(np.random.default_rng(seed))
    coef = fit_columns(X, y)
    rel = np.abs(coef - true) / true
    assert rel.max() <= 0.10, f"rel errors {dict(zip(COLUMNS, rel))}"


def test_nnls_matches_lstsq_when_interior():
    rng = np.random.default_rng(0)
    A = rng.random((30, 4)) + 0.1
    x_true = np.array([1.0, 2.0, 0.5, 3.0])
    b = A @ x_true
    assert np.allclose(nnls(A, b), x_true, atol=1e-8)


def test_nnls_clamps_negative_coordinates():
    A = np.array([[1.0, 0.0], [0.0, 1.0]])
    b = np.array([2.0, -1.0])
    x = nnls(A, b)
    assert np.allclose(x, [2.0, 0.0])
    assert (x >= 0).all()


def test_spearman_known_values():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [4, 3, 2, 1]) == pytest.approx(-1.0)
    # average-rank tie handling (scipy's value for this triple)
    assert spearman([1, 1, 2], [1, 2, 3]) == pytest.approx(
        np.sqrt(3) / 2)
    assert spearman([5, 5, 5], [1, 2, 3]) == 0.0


def test_reference_coefficients_price_like_analytic_model():
    """features · reference_coefficients == the analytic max-free part of
    the price — the 'pre-calibration' point is the hand-set model (up to
    the max(mem, compute) vs mem+compute difference, so ≥)."""
    csr = er(512, 4, seed=3)
    cm = CostModel(csr)
    ref = np.array([reference_coefficients()[c] for c in COLUMNS])
    for cfg in config_space(32)[:4]:
        bd = cm.cost(32, cfg)
        linear = float(breakdown_features(bd) @ ref)
        assert linear >= bd.total - 1e-12


# ------------------------------------------------------------- artifact
def _toy_samples(rng, ops=("spmm", "sddmm")):
    samples = []
    for op in ops:
        true = np.array([1e-5, 1 / 100e9, 1 / 1e11, 2e-7, 5e-7])
        if op == "sddmm":
            true = true * 2.0
        for _ in range(40):
            f = np.array([1.0, 10 ** rng.uniform(4, 8),
                          10 ** rng.uniform(5, 9),
                          10 ** rng.uniform(2, 6),
                          10 ** rng.uniform(1, 4)])
            t = float(f @ true)
            samples.append(CalibrationSample(
                "toy", op, 32, (1, 1, 1, False, False), f, t, t))
    return samples


def test_save_load_from_calibration_round_trip(tmp_path):
    """ISSUE acceptance: save → load → from_calibration round-trips
    bit-exact."""
    res = fit(_toy_samples(np.random.default_rng(0)),
              meta={"host": "test"})
    p1, p2 = tmp_path / "cal.json", tmp_path / "cal2.json"
    res.save(p1)
    res2 = CalibrationResult.load(p1)
    assert res2.to_dict() == res.to_dict()
    res2.save(p2)
    assert p1.read_bytes() == p2.read_bytes()    # byte-stable artifact

    csr = er(512, 4, seed=3)
    cm_mem = CostModel(csr, calibration=res)
    cm_file = CostModel.from_calibration(csr, p1)
    for cfg in config_space(32):
        assert cm_file.time(32, cfg) == cm_mem.time(32, cfg)
        assert cm_file.time(32, cfg, "sddmm") == cm_mem.time(
            32, cfg, "sddmm")


def test_artifact_column_mismatch_rejected():
    with pytest.raises(ValueError, match="columns"):
        CalibrationResult.from_dict(
            {"columns": ["const", "bytes"], "coef": {}})


def test_missing_op_falls_back_to_spmm():
    res = fit(_toy_samples(np.random.default_rng(1), ops=("spmm",)))
    assert np.array_equal(res.coefficients("gat"), res.coefficients("spmm"))


def test_stream_seconds_falls_back_to_analytic_bandwidth():
    res = CalibrationResult(coef={"spmm": dict(zip(
        COLUMNS, [1e-6, 0.0, 1e-12, 1e-7, 1e-7]))})
    assert res.stream_seconds(HBM_BW) == pytest.approx(1.0)
    res2 = CalibrationResult(coef={"spmm": dict(zip(
        COLUMNS, [1e-6, 2.0 / HBM_BW, 1e-12, 1e-7, 1e-7]))})
    assert res2.stream_seconds(HBM_BW) == pytest.approx(2.0)


# ----------------------------------------------- measured regression gates
@pytest.fixture(scope="module")
def gate():
    """One measured pass over the pinned gate design (GATE_GRAPHS ×
    GATE_DIMS × full config space, seeded, GATE_REPS reps) + its fit —
    shared by the rank gate and the regret gate."""
    samples = gate_design(reps=GATE_REPS)
    cal = fit(samples, meta={"design": "gate", "reps": GATE_REPS})
    return samples, cal


@pytest.mark.measured
def test_rank_correlation_gate(gate):
    """ISSUE acceptance: pooled priced-vs-measured Spearman ρ ≥ 0.5
    before calibration and ≥ 0.8 after, on the pinned small-corpus
    subset."""
    samples, cal = gate
    y = np.array([s.measured for s in samples])
    rho_pre = spearman(np.array([s.priced for s in samples]), y)
    rho_post = spearman(cal.predict(samples), y)
    assert rho_pre >= 0.5, f"pre-calibration rho {rho_pre:.3f} < 0.5"
    assert rho_post >= 0.8, f"post-calibration rho {rho_post:.3f} < 0.8"
    assert rho_post > rho_pre    # calibration must not make ranking worse


@pytest.mark.measured
def test_calibrated_best_regret(gate):
    """ISSUE acceptance: the calibrated ``CostModel.best`` pick is never
    >1.5× the measured-best config on any (graph, dim) of the gate
    design."""
    samples, cal = gate
    by_cell: dict = {}
    for s in samples:
        by_cell.setdefault((s.graph, s.dim), {})[s.config] = s.measured
    specs = {g.name: g for g in corpus("small")}
    for (gname, dim), times in by_cell.items():
        cm = CostModel(specs[gname].csr, calibration=cal)
        cfg, _ = cm.best(dim, config_space(dim))
        regret = times[cfg.astuple()] / min(times.values())
        assert regret <= 1.5, (
            f"{gname} dim={dim}: calibrated pick {cfg.astuple()} is "
            f"{regret:.2f}x the measured best")


def test_gate_design_is_pinned():
    """The regression gate only means something if its design cannot
    drift: graphs, dims, and reps are module constants."""
    assert GATE_GRAPHS == ("rmat10", "er1k", "ba1k")
    assert GATE_DIMS == (32, 64)
    assert GATE_REPS == 3
    names = {g.name for g in corpus("small")}
    assert set(GATE_GRAPHS) <= names
