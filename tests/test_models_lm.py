"""Per-arch smoke tests + decode-vs-forward consistency (cache path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.configs.base import ShapeCell
from repro.models import lm
from repro.models.transformer import logits_for


def _batch_for(cfg, cell, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in lm.input_specs(cfg, cell).items():
        if s.dtype == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, s.shape),
                                 jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return out


# scan/audio archs pay a 10-17 s trace each — deferred to the slow tier
_HEAVY_SMOKE = {"hymba-1.5b", "whisper-tiny", "rwkv6-1.6b"}
_SMOKE_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE else a
    for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _SMOKE_PARAMS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step, shapes + no NaNs."""
    cfg = get_reduced(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cell = ShapeCell("smoke", 32, 2, "train")
    batch = _batch_for(cfg, cell)
    loss, grads = jax.value_and_grad(
        lambda p: lm.train_loss(p, cfg, batch, chunk=16))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", _SMOKE_PARAMS)
def test_arch_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cell = ShapeCell("d", 32, 2, "decode")
    cache = lm.init_cache(cfg, cell)
    logits, new_cache = lm.decode_step(
        params, cfg, jnp.zeros((2, 1), jnp.int32), cache, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma2-27b", "chatglm3-6b",
                                  "granite-moe-1b-a400m", "hymba-1.5b",
                                  "rwkv6-1.6b", "llava-next-mistral-7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced incremental decode must reproduce the parallel
    forward logits — catches KV-cache indexing/masking/rope bugs."""
    cfg = get_reduced(arch)
    S, B = 12, 2
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        # patch prefix complicates position bookkeeping; decode cell uses
        # plain token stream (prefix folded at prefill in deployment)
        cfg = cfg.replace(n_patches=0)
        batch = {"tokens": tokens, "labels": tokens}
    h = lm.forward_hidden(params, cfg, batch, remat=False, chunk=S)
    ref = np.asarray(logits_for(h, params, cfg), np.float32)

    cell = ShapeCell("d", S, B, "decode")
    cache = lm.init_cache(cfg, cell)
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache, jnp.int32(t))
        outs.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=0.15, rtol=0.05)


@pytest.mark.slow
def test_whisper_decode_matches_forward():
    cfg = get_reduced("whisper-tiny")
    B, Sa, St = 2, 16, 12
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    frames = jnp.asarray(rng.standard_normal((B, Sa, cfg.d_model)),
                         jnp.bfloat16)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, St)), jnp.int32)
    from repro.models.whisper import (whisper_decode_train, whisper_encode)
    enc = whisper_encode(params, cfg, frames, remat=False)
    h = whisper_decode_train(params, cfg, tokens, enc, remat=False)
    ref = np.asarray(logits_for(h, params, cfg), np.float32)

    # build cross-attn K/V cache from encoder states (prefill step)
    L = cfg.n_layers
    xk = []
    xv = []
    for i in range(L):
        lp = jax.tree.map(lambda x: x[i], params["dec"])
        k = (enc @ lp["xwk"]).reshape(B, Sa, cfg.n_kv, cfg.head_dim)
        v = (enc @ lp["xwv"] + lp["xbv"]).reshape(B, Sa, cfg.n_kv,
                                                  cfg.head_dim)
        xk.append(k)
        xv.append(v)
    cache = {
        "k": jnp.zeros((L, B, St, cfg.n_kv, cfg.head_dim), jnp.bfloat16),
        "v": jnp.zeros((L, B, St, cfg.n_kv, cfg.head_dim), jnp.bfloat16),
        "xk": jnp.stack(xk).astype(jnp.bfloat16),
        "xv": jnp.stack(xv).astype(jnp.bfloat16),
    }
    outs = []
    for t in range(St):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache, jnp.int32(t))
        outs.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=0.2, rtol=0.05)


@pytest.mark.slow
def test_hymba_ring_buffer_beyond_window():
    """Decode past the SWA window: ring cache must keep exactly the last
    ``window`` keys (parallel forward with the same window as oracle)."""
    cfg = get_reduced("hymba-1.5b")           # window = 8
    S, B = 20, 1
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)
    h = lm.forward_hidden(params, cfg, {"tokens": tokens, "labels": tokens},
                          remat=False, chunk=S)
    ref = np.asarray(logits_for(h, params, cfg), np.float32)
    cell = ShapeCell("d", S, B, "decode")
    cache = lm.init_cache(cfg, cell)
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache, jnp.int32(t))
        outs.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=0.2, rtol=0.05)
