"""Fused GAT attention pipeline: fused SDDMM→softmax kernel vs the
engine oracle, multi-head batching, the dedicated transpose-PCSR backward,
and the slot transfer map's round-trip properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (_slot_rows, edge_softmax, engine_sddmm,
                               engine_spmm, make_gat_message_fn)
from repro.core.pcsr import (SpMMConfig, build_pcsr, slot_transfer_map,
                             transpose_pcsr)
from repro.core.sparse import CSRMatrix
from repro.kernels.sddmm import sddmm_softmax

from conftest import random_csr
from _propcheck import booleans, floats, integers, propcases, sampled_from

CONFIGS = [SpMMConfig(V=1, S=False, F=1, W=8),
           SpMMConfig(V=2, S=False, F=2, W=4),
           SpMMConfig(V=1, S=True, F=1, W=16),   # split chunks
           SpMMConfig(V=2, S=True, F=1, W=8)]    # split + vector padding


def _oracle_alpha(p, Q, K, slope=0.2):
    """Unfused reference: engine SDDMM → scale → LeakyReLU → segment
    softmax — the exact pipeline the fused kernel replaces."""
    arrs = p.to_jax()
    cfg = p.config
    scores = engine_sddmm(p, Q, K)
    mask = arrs["vals"] != 0
    rows = _slot_rows(arrs["lrow"], arrs["trow"], V=cfg.V, R=cfg.R, K=p.K)
    scaled = jax.nn.leaky_relu(
        scores / jnp.sqrt(jnp.float32(Q.shape[-1])), negative_slope=slope)
    return np.asarray(edge_softmax(scaled, mask, rows, p.n_blocks * cfg.R))


@pytest.mark.parametrize("cfg", CONFIGS, ids=str)
def test_fused_softmax_matches_engine_oracle(rng, cfg):
    csr, A = random_csr(rng, 67, 0.1)
    Q = rng.standard_normal((67, 40)).astype(np.float32)
    K = rng.standard_normal((67, 40)).astype(np.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 67, 67, cfg)
    alpha = np.asarray(sddmm_softmax(p, Q, K, interpret=True))
    np.testing.assert_allclose(alpha, _oracle_alpha(p, Q, K),
                               atol=1e-5, rtol=1e-5)


def test_fused_softmax_empty_rows_and_masked_edges(rng):
    # empty-row band + explicit-zero (masked) edges in the stored data
    n = 64
    A = ((rng.random((n, n)) < 0.2)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[8:40] = 0.0
    rows, cols = np.nonzero(A)
    vals = A[rows, cols].copy()
    vals[:: 5] = 0.0                      # every 5th stored edge masked out
    csr = CSRMatrix.from_coo(rows, cols, vals, n, n, sum_duplicates=False)
    Q = rng.standard_normal((n, 24)).astype(np.float32)
    K = rng.standard_normal((n, 24)).astype(np.float32)
    for cfg in (SpMMConfig(V=2, S=True, W=4), SpMMConfig(V=1, S=False, W=8)):
        p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n, cfg)
        alpha = np.asarray(sddmm_softmax(p, Q, K, interpret=True))
        oracle = _oracle_alpha(p, Q, K)
        np.testing.assert_allclose(alpha, oracle, atol=1e-5, rtol=1e-5)
        # masked slots carry exactly zero weight
        assert (alpha[np.asarray(p.vals) == 0] == 0).all()


@pytest.mark.parametrize("case", propcases(
    4, n=integers(8, 50), d=sampled_from([8, 40, 130]),
    density=floats(0.02, 0.3), v=sampled_from([1, 2]),
    s=booleans(), seed=integers(0, 99)), ids=str)
def test_fused_softmax_property(case):
    rng = np.random.default_rng(case.seed)
    csr, _ = random_csr(rng, case.n, case.density)
    Q = rng.standard_normal((case.n, case.d)).astype(np.float32)
    K = rng.standard_normal((case.n, case.d)).astype(np.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    alpha = np.asarray(sddmm_softmax(p, Q, K, interpret=True))
    np.testing.assert_allclose(alpha, _oracle_alpha(p, Q, K),
                               atol=1e-5, rtol=1e-5)


def test_fused_multihead_matches_per_head_and_compiles_once(rng, monkeypatch):
    import repro.kernels.sddmm.kernel as kmod
    csr, _ = random_csr(rng, 41, 0.15)
    H = 4
    Qh = rng.standard_normal((H, 41, 9)).astype(np.float32)
    Kh = rng.standard_normal((H, 41, 9)).astype(np.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 41, 41,
                   SpMMConfig(V=2, S=True, W=8))
    calls = []
    orig = kmod.sddmm_softmax_kernel
    monkeypatch.setattr(kmod, "sddmm_softmax_kernel",
                        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    batched = np.asarray(sddmm_softmax(p, Qh, Kh, interpret=True))
    # ≥4 heads, one head-tiled kernel trace — not a per-head loop/vmap
    assert len(calls) == 1
    per_head = np.stack([np.asarray(sddmm_softmax(p, Qh[h], Kh[h],
                                                  interpret=True))
                         for h in range(H)])
    np.testing.assert_allclose(batched, per_head, atol=1e-6, rtol=1e-6)


def test_gat_pallas_backward_no_engine_fallback(rng, monkeypatch):
    """The dedicated backward never touches the engine path."""
    import repro.core.engine as emod
    csr, _ = random_csr(rng, 40, 0.15)
    Q = rng.standard_normal((40, 16)).astype(np.float32)
    K = rng.standard_normal((40, 16)).astype(np.float32)
    Vf = rng.standard_normal((40, 12)).astype(np.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 40, 40,
                   SpMMConfig(V=2, S=True, W=8))
    f_eng = make_gat_message_fn(p, backend="engine")
    g_eng = jax.grad(lambda q, k, v: (f_eng(q, k, v) ** 2).sum(),
                     argnums=(0, 1, 2))(Q, K, Vf)
    f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)

    def _boom(*a, **kw):
        raise AssertionError("engine fallback in the Pallas GAT path")

    monkeypatch.setattr(emod, "_engine", _boom)
    monkeypatch.setattr(emod, "_engine_sddmm", _boom)
    monkeypatch.setattr(emod, "edge_softmax", _boom)
    g_pal = jax.grad(lambda q, k, v: (f_pal(q, k, v) ** 2).sum(),
                     argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(g_eng, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_gat_multihead_grad_matches_finite_differences(rng):
    """Pallas multi-head backward vs central differences."""
    n, d, H = 18, 4, 4
    csr, _ = random_csr(rng, n, 0.25)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n,
                   SpMMConfig(V=2, S=False, W=4))
    f = make_gat_message_fn(p, backend="pallas", interpret=True)
    Q = rng.standard_normal((H, n, d)).astype(np.float32)
    K = rng.standard_normal((H, n, d)).astype(np.float32)
    Vf = rng.standard_normal((H, n, 3)).astype(np.float32)
    w = jnp.asarray(rng.standard_normal((H, n, 3)), jnp.float32)

    def loss(q, k, v):
        return (f(q, k, v) * w).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2))(Q, K, Vf)
    eps = 1e-3
    for ai, arr in enumerate((Q, K, Vf)):
        g = np.asarray(grads[ai])
        for idx in [(0, 0, 0), (1, 3, 2),
                    (H - 1, arr.shape[1] - 1, arr.shape[2] - 1)]:
            up, dn = arr.copy(), arr.copy()
            up[idx] += eps
            dn[idx] -= eps
            args_u, args_d = [Q, K, Vf], [Q, K, Vf]
            args_u[ai], args_d[ai] = up, dn
            fd = (float(loss(*args_u)) - float(loss(*args_d))) / (2 * eps)
            np.testing.assert_allclose(g[idx], fd, atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("case", propcases(
    6, n=integers(8, 40), density=floats(0.05, 0.3),
    v=sampled_from([1, 2]), s=booleans(), seed=integers(0, 99)), ids=str)
def test_transpose_pcsr_roundtrip_property(case):
    rng = np.random.default_rng(case.seed)
    csr, A = random_csr(rng, case.n, case.density)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    p_t = transpose_pcsr(p)
    f_idx, t_idx = slot_transfer_map(p, p_t)
    assert f_idx.shape[0] == csr.nnz == t_idx.shape[0]
    # transferring A's stored values lands exactly on Aᵀ-PCSR's own values
    tv = np.zeros(p_t.num_chunks * p_t.config.V * p_t.K, np.float32)
    tv[t_idx] = p.vals.reshape(-1)[f_idx]
    np.testing.assert_array_equal(tv.reshape(p_t.vals.shape), p_t.vals)
    # round-trip: fwd → transpose → fwd recovers an arbitrary slot tensor
    x = np.zeros(p.vals.size, np.float32)
    x[f_idx] = rng.standard_normal(f_idx.shape[0]).astype(np.float32)
    tvx = np.zeros_like(tv)
    tvx[t_idx] = x[f_idx]
    back = np.zeros_like(x)
    back[f_idx] = tvx[t_idx]
    np.testing.assert_array_equal(back, x)
    # and the transpose PCSR really computes Aᵀ·B
    B = rng.standard_normal((case.n, 8)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(engine_spmm(p_t, B)), A.T @ B,
                               atol=1e-4, rtol=1e-4)
