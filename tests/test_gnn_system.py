"""End-to-end GNN behaviour (paper's application layer)."""
import numpy as np
import pytest

from repro.apps.gnn import train_gnn
from repro.data.tasks import community_task
from repro.pipeline import ParamSpMM
from repro.core.sparse import CSRMatrix
from repro.kernels.paramspmm import spmm_ref


@pytest.fixture(scope="module")
def task():
    return community_task(n_blocks=6, block_size=64, feat_dim=16,
                          p_in=0.2, noise=1.0, seed=2)


@pytest.mark.slow
def test_gcn_converges_with_paramspmm(task):
    r = train_gnn(task, model="gcn", hidden=32, n_layers=3, steps=50,
                  spmm_mode="paramspmm")
    assert r.val_acc > 0.9
    assert r.losses[-1] < r.losses[0] * 0.2


@pytest.mark.slow
def test_gin_converges(task):
    r = train_gnn(task, model="gin", hidden=32, n_layers=3, steps=80,
                  spmm_mode="paramspmm", lr=2e-3)
    assert r.val_acc > 0.5                 # GIN trains slower; > 3× chance
    assert r.losses[-1] < r.losses[0]


def test_paramspmm_agg_equals_baseline_agg(task):
    """Same training trajectory whichever SpMM backend aggregates.
    ``fused=False`` keeps the classic (A·h)·W association so the
    comparison against the never-fused baseline is apples-to-apples."""
    a = train_gnn(task, model="gcn", hidden=32, n_layers=3, steps=10,
                  spmm_mode="paramspmm", fused=False,
                  spmm_kwargs={"reorder": False})
    b = train_gnn(task, model="gcn", hidden=32, n_layers=3, steps=10,
                  spmm_mode="cusparse")
    np.testing.assert_allclose(a.losses, b.losses, rtol=2e-3, atol=2e-3)


def test_gcn_fused_epilogue_trajectory_close_to_unfused(task):
    """The fused path (Â·(H·W) + bias/ReLU in the SpMM epilogue) is the
    same math reassociated — trajectories stay close over a short run."""
    a = train_gnn(task, model="gcn", hidden=32, n_layers=3, steps=8,
                  spmm_mode="paramspmm", fused=True,
                  spmm_kwargs={"reorder": False})
    b = train_gnn(task, model="gcn", hidden=32, n_layers=3, steps=8,
                  spmm_mode="paramspmm", fused=False,
                  spmm_kwargs={"reorder": False})
    np.testing.assert_allclose(a.losses, b.losses, rtol=1e-3, atol=1e-3)
    assert a.losses[-1] < a.losses[0]


def test_pipeline_matches_ref(task):
    import jax.numpy as jnp
    csr = task.csr.gcn_normalize()
    p = ParamSpMM(csr, 32, reorder=False)
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((csr.n_cols, 32)), jnp.float32)
    ref = spmm_ref(csr.indptr, csr.indices, csr.data, B, csr.n_rows)
    np.testing.assert_allclose(np.asarray(p(B)), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_gat_loss_decreases_engine(task):
    """Attention GNN: short train run through SDDMM→softmax→SpMM."""
    r = train_gnn(task, model="gat", hidden=16, n_layers=2, steps=8,
                  spmm_mode="paramspmm", lr=1e-2,
                  spmm_kwargs={"reorder": False})
    assert np.isfinite(r.losses).all()
    assert r.losses[-1] < r.losses[0]


@pytest.mark.slow
def test_gat_converges(task):
    r = train_gnn(task, model="gat", hidden=32, n_layers=2, steps=60,
                  spmm_mode="paramspmm", lr=5e-3)
    assert r.val_acc > 0.8
    assert r.losses[-1] < r.losses[0] * 0.5


def test_gat_pallas_backend_trains_multihead():
    """All-Pallas trainable GAT: fused SDDMM→softmax forward, dedicated
    transpose-PCSR backward (no engine fallback — enforced by the
    monkeypatch test in test_gat_fused.py), 2 heads in one kernel call."""
    from repro.data.tasks import community_task
    small = community_task(n_blocks=3, block_size=24, feat_dim=8,
                           p_in=0.3, noise=0.5, seed=1)
    r = train_gnn(small, model="gat", hidden=8, n_layers=2, steps=3,
                  spmm_mode="paramspmm", lr=1e-2, heads=2,
                  spmm_kwargs={"reorder": False, "backend": "pallas",
                               "interpret": True})
    assert np.isfinite(r.losses).all()
    assert r.losses[-1] < r.losses[0]


@pytest.mark.slow
def test_gat_pallas_backend_trains():
    from repro.data.tasks import community_task
    small = community_task(n_blocks=3, block_size=32, feat_dim=8,
                           p_in=0.3, noise=0.5, seed=1)
    r = train_gnn(small, model="gat", hidden=8, n_layers=2, steps=4,
                  spmm_mode="paramspmm", lr=1e-2,
                  spmm_kwargs={"reorder": False, "backend": "pallas",
                               "interpret": True})
    assert np.isfinite(r.losses).all()
    assert r.losses[-1] < r.losses[0]


@pytest.mark.slow
def test_gat_multihead_converges(task):
    r = train_gnn(task, model="gat", hidden=32, n_layers=2, steps=60,
                  spmm_mode="paramspmm", lr=5e-3, heads=4)
    assert r.val_acc > 0.8
    assert r.losses[-1] < r.losses[0] * 0.5


def test_pipeline_reorder_consistency(task):
    """Reordered pipeline computes P·A·Pᵀ — un-permuting recovers A·B."""
    import jax.numpy as jnp
    csr = task.csr.gcn_normalize()
    p = ParamSpMM(csr, 16, reorder=True)
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((csr.n_cols, 16)), jnp.float32)
    perm = p.perm
    Bp = B[jnp.asarray(np.argsort(perm))]       # B in reordered space
    out = np.asarray(p(Bp))
    ref = np.asarray(spmm_ref(csr.indptr, csr.indices, csr.data, B,
                              csr.n_rows))
    np.testing.assert_allclose(out[perm], ref, atol=1e-4, rtol=1e-4)
