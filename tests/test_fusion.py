"""Prologue/epilogue fusion layer: the two-kernel GAT forward (kernel-count
asserted), the fused-epilogue GCN aggregation, flash-style recompute
backward, covered steering arrays, and the head-aware cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import CostModel, unfused_penalty
from repro.core.engine import (ParamSpMMOperator, engine_spmm,
                               engine_spmm_fused, make_gat_message_fn)
from repro.core.pcsr import (LANES, SUBLANES, SpMMConfig, build_pcsr,
                             config_space)
from repro.core.sparse import CSRMatrix
from repro.kernels.paramspmm.ops import paramspmm, paramspmm_with_vals
from repro.kernels.sddmm.ops import sddmm_softmax, sddmm_softmax_stats

from conftest import random_csr
from _propcheck import booleans, floats, integers, propcases, sampled_from


def _empty_band_csr(rng, n, density, lo, hi):
    """Matrix with a fully-empty row band → empty PCSR blocks."""
    A = ((rng.random((n, n)) < density)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[lo:hi] = 0.0
    return CSRMatrix.from_dense(A), A


# ------------------------------------------------------- kernel counts
def _count_pallas_calls(monkeypatch, fn):
    """The SAME interception `bench_fusion` records into BENCH_spmm.json
    (benchmarks/common.count_pallas_calls) — one definition of "a kernel
    launch", so the test assertion and the archived artifact agree."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import count_pallas_calls
    return count_pallas_calls(fn)


def test_gat_forward_is_exactly_two_kernels(rng, monkeypatch):
    """The acceptance bar: the fused GAT forward launches exactly two
    Pallas kernels — sddmm_softmax_stats + the prologue SpMM — with no
    interstitial elementwise normalize (α never materializes)."""
    csr, _ = random_csr(rng, 37, 0.2)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 37, 37,
                   SpMMConfig(V=2, S=True, W=8, F=1))
    f = make_gat_message_fn(p, backend="pallas", interpret=True)
    Q = jnp.asarray(rng.standard_normal((37, 11)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((37, 11)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((37, 10)), jnp.float32)
    calls = _count_pallas_calls(monkeypatch, lambda: f(Q, K, Vf))
    assert len(calls) == 2, calls
    assert any("sddmm_softmax" in c for c in calls)
    assert any("_pro" in c for c in calls)      # prologue-fused SpMM


def test_gin_aggregation_is_one_kernel(rng, monkeypatch):
    """Residual epilogue: GIN's ``(1+ε)h + A·h`` aggregation is ONE
    kernel launch — the ``(1+ε)h`` operand rides the VMEM-resident
    output block as the fused residual addend."""
    from repro.models.gnn import gin_forward, init_gin

    csr, _ = random_csr(rng, 37, 0.15)
    op = ParamSpMMOperator(csr, SpMMConfig(V=2, S=True, W=4),
                           backend="pallas", interpret=True)
    params = init_gin(jax.random.PRNGKey(0), [13, 13])
    X = jnp.asarray(rng.standard_normal((37, 13)), jnp.float32)
    calls = _count_pallas_calls(monkeypatch,
                                lambda: gin_forward(params, X, op))
    assert len(calls) == 1, calls
    assert "_res" in calls[0]                  # residual-fused kernel
    ref = gin_forward(params, X, lambda h: engine_spmm(op.pcsr, h))
    np.testing.assert_allclose(np.asarray(gin_forward(params, X, op)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_gcn_aggregation_is_one_kernel(rng, monkeypatch):
    """Epilogue fusion: aggregate + degree-scale + bias + ReLU = ONE
    kernel launch, not kernel + elementwise passes."""
    csr, A = random_csr(rng, 39, 0.15)
    op = ParamSpMMOperator(csr, SpMMConfig(V=1, S=False, W=8),
                           backend="pallas", interpret=True)
    B = jnp.asarray(rng.standard_normal((39, 13)), jnp.float32)
    sc = jnp.asarray(rng.random(39), jnp.float32)
    b = jnp.asarray(rng.standard_normal(13), jnp.float32)
    calls = _count_pallas_calls(
        monkeypatch,
        lambda: op.fused(B, scale=sc, bias=b, activation="relu"))
    assert len(calls) == 1, calls
    out = np.asarray(op.fused(B, scale=sc, bias=b, activation="relu"))
    ref = np.maximum(np.asarray(sc)[:, None] * (A @ np.asarray(B))
                     + np.asarray(b), 0.0)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


# ------------------------------------------- unvisited-block zeroing
def test_unvisited_blocks_zeroed_in_kernel_no_mask_pass(rng):
    """Empty blocks are zeroed by the kernel's own init path via coverage
    chunks — outputs exact zeros with no post-kernel jnp.where pass."""
    csr, A = _empty_band_csr(rng, 64, 0.2, 8, 40)
    B = jnp.asarray(rng.standard_normal((64, 20)), jnp.float32)
    for cfg in (SpMMConfig(V=2, S=True, W=4), SpMMConfig(V=1, S=False, W=8)):
        p = build_pcsr(csr.indptr, csr.indices, csr.data, 64, 64, cfg)
        st = p.steering(covered=True)
        # coverage really exists and targets every block exactly once
        assert set(st["trow"].tolist()) == set(range(p.n_blocks))
        out = np.asarray(paramspmm(p, B, interpret=True))
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, A @ np.asarray(B),
                                   atol=1e-4, rtol=1e-4)
        assert (out[8:40] == 0).all()


@pytest.mark.parametrize("case", propcases(
    4, n=integers(8, 50), density=floats(0.02, 0.3),
    v=sampled_from([1, 2]), s=booleans(), h=sampled_from([1, 4]),
    seed=integers(0, 99)), ids=str)
def test_covered_steering_prefix_property(case):
    """Covered arrays = uncovered arrays + appended all-padding chunks
    (the prefix property the distributed packing slices by)."""
    rng = np.random.default_rng(case.seed)
    csr, _ = random_csr(rng, case.n, case.density)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    plain, cov = p.steering(case.h), p.steering(case.h, covered=True)
    per_head = cov["trow"].shape[0] // case.h
    E = per_head - p.num_chunks
    assert E == p.n_empty_blocks
    for key in ("colidx", "lrow", "trow", "init", "fini"):
        a, b = plain[key], cov[key]
        stride_a, stride_b = a.shape[0] // case.h, b.shape[0] // case.h
        for h in range(case.h):               # per head: prefix match
            np.testing.assert_array_equal(
                b[h * stride_b:h * stride_b + stride_a],
                a[h * stride_a:(h + 1) * stride_a])
    # appended chunks are all-padding, first+last of their (empty) block
    if E:
        tail = slice(p.num_chunks, per_head)
        assert (cov["init"][tail] == 1).all()
        assert (cov["fini"][tail] == 1).all()
        assert (cov["vals"].reshape(case.h, per_head, -1)[0, p.num_chunks:]
                == 0).all()
    # fini marks exactly one last chunk per targeted block
    assert cov["fini"].sum() == len(set(cov["trow"].tolist()))


# ------------------------------------------------- fused vs engine ref
@pytest.mark.parametrize("case", propcases(
    6, n=integers(8, 48), d=sampled_from([8, 40, 130]),
    density=floats(0.02, 0.3), v=sampled_from([1, 2]),
    s=booleans(), h=sampled_from([1, 3]), seed=integers(0, 99)), ids=str)
def test_two_kernel_gat_matches_engine_property(case):
    """Fused prologue GAT forward == unfused engine path, across split
    chunks, vector padding, and multi-head batches."""
    rng = np.random.default_rng(case.seed)
    csr, _ = random_csr(rng, case.n, case.density)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    f_eng = make_gat_message_fn(p, backend="engine")
    f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)
    shape = (case.n, case.d) if case.h == 1 else (case.h, case.n, case.d)
    vshape = (case.n, 6) if case.h == 1 else (case.h, case.n, 6)
    Q = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    K = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal(vshape), jnp.float32)
    np.testing.assert_allclose(np.asarray(f_pal(Q, K, Vf)),
                               np.asarray(f_eng(Q, K, Vf)),
                               atol=1e-4, rtol=1e-4)


def test_two_kernel_gat_empty_rows_and_masked_edges(rng):
    """Empty destination rows (garbage stats rows) and explicit-zero
    (masked) edges must come out exactly as the engine says — the −inf
    logit convention + prologue guards keep padding at exactly 0."""
    n = 64
    A = ((rng.random((n, n)) < 0.2)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[8:40] = 0.0
    rows, cols = np.nonzero(A)
    vals = A[rows, cols].copy()
    vals[::5] = 0.0                      # every 5th stored edge masked out
    csr = CSRMatrix.from_coo(rows, cols, vals, n, n, sum_duplicates=False)
    Q = jnp.asarray(rng.standard_normal((n, 24)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((n, 24)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((n, 12)), jnp.float32)
    for cfg in (SpMMConfig(V=2, S=True, W=4), SpMMConfig(V=1, S=False, W=8)):
        p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n, cfg)
        f_eng = make_gat_message_fn(p, backend="engine")
        f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)
        out = np.asarray(f_pal(Q, K, Vf))
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, np.asarray(f_eng(Q, K, Vf)),
                                   atol=1e-4, rtol=1e-4)
        assert (out[8:40] == 0).all()    # empty rows aggregate nothing


@pytest.mark.parametrize("case", propcases(
    4, n=integers(8, 40), density=floats(0.05, 0.3),
    v=sampled_from([1, 2]), s=booleans(),
    act=sampled_from(["none", "relu", "leaky_relu"]),
    seed=integers(0, 99)), ids=str)
def test_fused_epilogue_matches_engine_property(case):
    """Epilogue fusion == engine reference act(scale ⊙ A·B + bias), with
    empty rows receiving exactly act(bias)."""
    rng = np.random.default_rng(case.seed)
    csr, _ = _empty_band_csr(rng, case.n, case.density,
                             case.n // 4, case.n // 2)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    B = jnp.asarray(rng.standard_normal((case.n, 9)), jnp.float32)
    sc = jnp.asarray(rng.random(case.n) + 0.5, jnp.float32)
    b = jnp.asarray(rng.standard_normal(9), jnp.float32)
    out = np.asarray(paramspmm(p, B, scale=sc, bias=b,
                               activation=case.act, interpret=True))
    ref = np.asarray(engine_spmm_fused(p, B, scale=sc, bias=b,
                                       activation=case.act))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
    # empty rows = act(0 + bias), NOT uninitialized memory
    band = np.asarray(engine_spmm_fused(
        p, jnp.zeros_like(B), scale=sc, bias=b, activation=case.act))
    np.testing.assert_allclose(out[case.n // 4:case.n // 2],
                               band[case.n // 4:case.n // 2],
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("case", propcases(
    4, n=integers(8, 40), density=floats(0.05, 0.3),
    v=sampled_from([1, 2]), s=booleans(),
    act=sampled_from(["none", "relu", "leaky_relu"]),
    with_scale=booleans(), with_bias=booleans(),
    seed=integers(0, 99)), ids=str)
def test_fused_residual_epilogue_matches_engine_property(case):
    """Residual epilogue == engine act(scale ⊙ A·B + bias + residual),
    composed with every other epilogue operand; empty rows receive
    exactly act(bias + residual)."""
    rng = np.random.default_rng(case.seed)
    csr, _ = _empty_band_csr(rng, case.n, case.density,
                             case.n // 4, case.n // 2)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    B = jnp.asarray(rng.standard_normal((case.n, 9)), jnp.float32)
    res = jnp.asarray(rng.standard_normal((case.n, 9)), jnp.float32)
    sc = (jnp.asarray(rng.random(case.n) + 0.5, jnp.float32)
          if case.with_scale else None)
    b = (jnp.asarray(rng.standard_normal(9), jnp.float32)
         if case.with_bias else None)
    out = np.asarray(paramspmm(p, B, scale=sc, bias=b, residual=res,
                               activation=case.act, interpret=True))
    ref = np.asarray(engine_spmm_fused(p, B, scale=sc, bias=b,
                                       residual=res, activation=case.act))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
    band = np.asarray(engine_spmm_fused(
        p, jnp.zeros_like(B), scale=sc, bias=b, residual=res,
        activation=case.act))
    np.testing.assert_allclose(out[case.n // 4:case.n // 2],
                               band[case.n // 4:case.n // 2],
                               atol=1e-5, rtol=1e-5)


def test_residual_epilogue_is_single_head_only(rng):
    csr, _ = random_csr(rng, 24, 0.2)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 24, 24,
                   SpMMConfig(V=2, S=True, W=4))
    B3 = jnp.ones((2, 24, 8), jnp.float32)
    with pytest.raises(NotImplementedError, match="single-head"):
        paramspmm_with_vals(p, None, B3,
                            residual=jnp.ones((24, 8), jnp.float32))


def test_residual_operand_is_priced():
    rng = np.random.default_rng(2)
    csr, _ = random_csr(rng, 300, 0.05)
    cm = CostModel(csr)
    cfg = SpMMConfig(V=1, S=True, W=8)
    plain = cm.cost(64, cfg)
    resid = cm.cost(64, cfg, residual=True)
    # the addend read mirrors the output-write traffic
    assert resid.bytes_meta - plain.bytes_meta == plain.bytes_out
    assert resid.total > plain.total


# ----------------------------------------------------------- gradients
def test_fused_residual_grads_match_engine_and_fd(rng):
    """d/dresidual of the fused epilogue is dpre (the add is linear):
    engine and Pallas custom_vjps agree with each other and with finite
    differences, and ε-gradients flow through GIN's fused path."""
    csr, _ = random_csr(rng, 32, 0.2)
    cfg = SpMMConfig(V=2, S=True, W=4)
    ope = ParamSpMMOperator(csr, cfg, backend="engine")
    opp = ParamSpMMOperator(csr, cfg, backend="pallas", interpret=True)
    B = jnp.asarray(rng.standard_normal((32, 6)), jnp.float32)
    res = jnp.asarray(rng.standard_normal((32, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(6), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 6)), jnp.float32)

    def loss(op):
        return lambda B, b, res: (op.fused(B, bias=b, residual=res,
                                           activation="relu") * w).sum()

    ge = jax.grad(loss(ope), (0, 1, 2))(B, b, res)
    gp = jax.grad(loss(opp), (0, 1, 2))(B, b, res)
    for a, c in zip(ge, gp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-4, rtol=1e-4)
    lp = loss(opp)
    eps = 1e-3
    g = np.asarray(gp[2])
    flat = np.asarray(res).reshape(-1)
    for idx in (0, flat.size // 2, flat.size - 1):
        up, dn = flat.copy(), flat.copy()
        up[idx] += eps
        dn[idx] -= eps
        fd = (float(lp(B, b, jnp.asarray(up.reshape(32, 6))))
              - float(lp(B, b, jnp.asarray(dn.reshape(32, 6))))) / (2 * eps)
        np.testing.assert_allclose(g.reshape(-1)[idx], fd,
                                   atol=5e-2, rtol=5e-2)
    # ε-gradient through GIN's fused aggregation matches the unfused form
    from repro.models.gnn import gin_forward, init_gin
    params = init_gin(jax.random.PRNGKey(1), [6, 6])
    X = jnp.asarray(rng.standard_normal((32, 6)), jnp.float32)
    gf = jax.grad(lambda pp: (gin_forward(pp, X, opp) ** 2).sum())(params)
    gu = jax.grad(lambda pp: (gin_forward(
        pp, X, lambda h: engine_spmm(opp.pcsr, h)) ** 2).sum())(params)
    for key in gf[0]:
        np.testing.assert_allclose(np.asarray(gf[0][key]),
                                   np.asarray(gu[0][key]),
                                   atol=1e-3, rtol=1e-3)


def test_fused_gcn_layer_grads_match_engine_and_fd(rng):
    csr, _ = random_csr(rng, 32, 0.2)
    cfg = SpMMConfig(V=2, S=True, W=4)
    ope = ParamSpMMOperator(csr, cfg, backend="engine")
    opp = ParamSpMMOperator(csr, cfg, backend="pallas", interpret=True)
    B = jnp.asarray(rng.standard_normal((32, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(6), jnp.float32)
    sc = jnp.asarray(rng.random(32) + 0.5, jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 6)), jnp.float32)

    def loss(op):
        return lambda B, b: (op.fused(B, scale=sc, bias=b,
                                      activation="relu") * w).sum()

    ge = jax.grad(loss(ope), (0, 1))(B, b)
    gp = jax.grad(loss(opp), (0, 1))(B, b)
    for a, c in zip(ge, gp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-4, rtol=1e-4)
    # finite differences on a few coordinates of B and bias (small eps:
    # a large step walks output coordinates across the ReLU kink)
    lp = loss(opp)
    eps = 1e-3
    for ai, arr in enumerate((B, b)):
        g = np.asarray(gp[ai])
        flat = np.asarray(arr).reshape(-1)
        for idx in (0, flat.size // 2, flat.size - 1):
            up, dn = flat.copy(), flat.copy()
            up[idx] += eps
            dn[idx] -= eps
            args_u = [B, b]
            args_d = [B, b]
            args_u[ai] = jnp.asarray(up.reshape(np.shape(arr)))
            args_d[ai] = jnp.asarray(dn.reshape(np.shape(arr)))
            fd = (float(lp(*args_u)) - float(lp(*args_d))) / (2 * eps)
            np.testing.assert_allclose(g.reshape(-1)[idx], fd,
                                       atol=5e-2, rtol=5e-2)


def test_gat_recompute_backward_drops_alpha_residual(rng):
    """Flash-style recompute: the saved residuals are logits + row stats
    only — no (C, V, K) α tensor — and the grads still match the engine."""
    csr, _ = random_csr(rng, 40, 0.15)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 40, 40,
                   SpMMConfig(V=2, S=True, W=8))
    f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)
    Q = jnp.asarray(rng.standard_normal((40, 16)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((40, 16)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((40, 12)), jnp.float32)
    out, vjp = jax.vjp(f_pal, Q, K, Vf)
    # residuals: Q, K, Vf mirrors + logits (C, V, K) + 2 tile-aligned
    # stats (nb·SUBLANES, LANES) — an α-shaped residual would make it
    # 2 slot-shaped tensors, not 1
    slot_shaped = [x for x in jax.tree_util.tree_leaves(vjp)
                   if np.shape(x) == (p.num_chunks, p.config.V, p.K)]
    assert len(slot_shaped) == 1        # the logits — α is NOT stored
    stats_shaped = [x for x in jax.tree_util.tree_leaves(vjp)
                    if np.shape(x) == (p.n_blocks * SUBLANES, LANES)]
    assert len(stats_shaped) == 2       # rowmax + rowsum
    f_eng = make_gat_message_fn(p, backend="engine")
    g_eng = jax.grad(lambda q, k, v: (f_eng(q, k, v) ** 2).sum(),
                     argnums=(0, 1, 2))(Q, K, Vf)
    g_pal = vjp(2.0 * out)
    for a, b in zip(g_eng, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_fully_fused_gat_multihead_grad_finite_differences(rng):
    """FD check through the 2-kernel forward + recompute backward."""
    n, d, H = 18, 4, 4
    csr, _ = random_csr(rng, n, 0.25)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n,
                   SpMMConfig(V=2, S=False, W=4))
    f = make_gat_message_fn(p, backend="pallas", interpret=True)
    Q = rng.standard_normal((H, n, d)).astype(np.float32)
    K = rng.standard_normal((H, n, d)).astype(np.float32)
    Vf = rng.standard_normal((H, n, 3)).astype(np.float32)
    w = jnp.asarray(rng.standard_normal((H, n, 3)), jnp.float32)

    def loss(q, k, v):
        return (f(q, k, v) * w).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2))(Q, K, Vf)
    eps = 1e-3
    for ai, arr in enumerate((Q, K, Vf)):
        g = np.asarray(grads[ai])
        for idx in [(0, 0, 0), (1, 3, 2),
                    (H - 1, arr.shape[1] - 1, arr.shape[2] - 1)]:
            up, dn = arr.copy(), arr.copy()
            up[idx] += eps
            dn[idx] -= eps
            args_u, args_d = [Q, K, Vf], [Q, K, Vf]
            args_u[ai], args_d[ai] = up, dn
            fd = (float(loss(*args_u)) - float(loss(*args_d))) / (2 * eps)
            np.testing.assert_allclose(g[idx], fd, atol=5e-2, rtol=5e-2)


# ------------------------------------------------- head-aware pricing
def test_cost_model_best_gat_differs_across_heads():
    """Regression for the head-aware cost model: head tiling multiplies
    C/n_blocks and shrinks the per-head dim, so the optimal F (at least)
    must be able to change with H."""
    rng = np.random.default_rng(0)
    n = 1500
    A = (rng.random((n, n)) < 0.004)
    rows, cols = np.nonzero(A)
    csr = CSRMatrix.from_coo(rows, cols, np.ones(len(rows), np.float32),
                             n, n)
    cm = CostModel(csr)
    space = config_space(512, max_f=4)
    best = {H: cm.best(512, space, op="gat", H=H)[0] for H in (1, 8)}
    assert best[1] != best[8], best
    # and the pricing is strictly head-sensitive, not just rescaled
    t1 = cm.time(512, best[1], "gat", H=1)
    t8 = cm.time(512, best[1], "gat", H=8)
    assert t8 > t1


def test_cost_model_fusion_savings_positive():
    rng = np.random.default_rng(1)
    csr, _ = random_csr(rng, 300, 0.05)
    cm = CostModel(csr)
    cfg = SpMMConfig(V=1, S=True, W=8)
    assert cm.fusion_savings(64, cfg, op="gat") > 0
    assert cm.fusion_savings(64, cfg, op="spmm") > 0
    assert (cm.time(64, cfg, "gat", fused=False)
            == pytest.approx(cm.time(64, cfg, "gat")
                             + unfused_penalty(cm.stats(1, 8), 64, cfg,
                                               "gat")))


def test_fused_gat_pipeline_prices_per_head_config(rng):
    """ParamSpMM(op='gat', heads=H) feeds H into the cost model."""
    from repro.pipeline import ParamSpMM
    csr, _ = random_csr(rng, 200, 0.08)
    p1 = ParamSpMM(csr, 256, reorder=False, op="gat", heads=1)
    p8 = ParamSpMM(csr, 256, reorder=False, op="gat", heads=8)
    cm = CostModel(csr)
    space = config_space(256)
    assert p1.config == cm.best(256, space, op="gat", H=1)[0]
    assert p8.config == cm.best(256, space, op="gat", H=8)[0]
