"""Tiny seeded random-case generator — offline stand-in for hypothesis.

Each strategy is a callable ``rng -> value``; ``propcases`` materializes
``max_examples`` deterministic draws (numpy ``default_rng``) into a list of
dicts suitable for ``pytest.mark.parametrize``.  Coverage is equivalent to
``@given(...)`` with a fixed seed: N random points from the same domains,
reproducible across runs.
"""
import numpy as np


def integers(lo, hi):
    return lambda rng: int(rng.integers(lo, hi + 1))


def floats(lo, hi):
    return lambda rng: float(rng.uniform(lo, hi))


def sampled_from(options):
    return lambda rng: options[int(rng.integers(0, len(options)))]


def booleans():
    return lambda rng: bool(rng.integers(0, 2))


class Case(dict):
    """Dict with attribute access and a stable pytest id."""

    def __getattr__(self, name):
        try:
            return self[name]
        except KeyError:
            raise AttributeError(name) from None

    def __str__(self):
        return "-".join(f"{k}={v}" for k, v in self.items())


def propcases(max_examples, _seed=0, **strategies):
    # leading underscore: strategies often include a literal "seed" kwarg
    rng = np.random.default_rng(_seed)
    return [Case({k: draw(rng) for k, draw in strategies.items()})
            for _ in range(max_examples)]
