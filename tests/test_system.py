"""End-to-end behaviour of the paper's system (Fig. 2 three-phase flow):
features → decider/oracle config → PCSR → engine, embedded in GNN
training, with the adaptivity claims checked as system-level assertions."""
import numpy as np
import pytest

from repro.core.autotune import oracle_search
from repro.core.cost_model import CostModel
from repro.core.features import extract_features
from repro.core.pcsr import config_space
from repro.data.graphs import clones, grid2d, rmat
from repro.pipeline import ParamSpMM


def test_adaptive_configs_differ_across_inputs():
    """The system's core claim: optimal ⟨W,F,V,S⟩ varies with input."""
    skew = rmat(10, 8, seed=1)
    local = clones(2000, 10, seed=2)
    flat = grid2d(40, seed=3)
    cfgs = {ParamSpMM(g, 64, reorder=False).config.astuple()
            for g in (skew, local, flat)}
    assert len(cfgs) >= 2


def test_oracle_beats_worst_config_substantially():
    g = rmat(11, 8, seed=4)
    res = oracle_search(g, 64, mode="model")
    worst = max(res.times.values())
    assert worst / res.best_time > 1.5


def test_decider_features_track_structure():
    f_skew = extract_features(rmat(10, 8, seed=5)).as_dict()
    f_flat = extract_features(grid2d(32, seed=5)).as_dict()
    assert f_skew["cv"] > 1.0 > f_flat["cv"]
    f_loc = extract_features(clones(1500, 10, seed=6)).as_dict()
    f_sh = extract_features(clones(1500, 10, seed=6, shuffle=True)).as_dict()
    assert f_loc["pr_2"] < f_sh["pr_2"]


def test_end_to_end_spmm_correct_under_predicted_config():
    import jax.numpy as jnp
    from repro.kernels.paramspmm import spmm_ref
    g = clones(1000, 8, seed=7)
    p = ParamSpMM(g, 32, reorder=False)
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((g.n_cols, 32)), jnp.float32)
    ref = spmm_ref(g.indptr, g.indices, g.data, B, g.n_rows)
    np.testing.assert_allclose(np.asarray(p(B)), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
