"""Balanced (B-mode) chunk schedule: packer invariants, value-exactness
against the engine oracle on degree-skewed graphs, transpose round-trip
through the GAT backward, cost-model selection, head-aware oracle labels,
and the fully-masked-row softmax guards (forward AND flash backward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import oracle_search
from repro.core.cost_model import CostModel
from repro.core.engine import engine_spmm, make_gat_message_fn
from repro.core.pcsr import (SUBLANES, SpMMConfig, balanced_capacity,
                             build_pcsr, config_space, transpose_pcsr)
from repro.core.sparse import CSRMatrix
from repro.data.graphs import ba, corpus, kregular, rmat
from repro.kernels.paramspmm.ops import paramspmm

from conftest import random_csr
from test_pcsr import _dense_from_pcsr
from _propcheck import floats, integers, propcases, sampled_from


def _build(csr, cfg):
    return build_pcsr(csr.indptr, csr.indices, csr.data,
                      csr.n_rows, csr.n_cols, cfg)


def _chunk_pop(p):
    """Occupied vector-slots per chunk (a slot is occupied when any of
    its V values is nonzero)."""
    return (np.asarray(p.vals) != 0).any(axis=1).sum(axis=1)


# ------------------------------------------------------------- packer
def test_config_validation():
    with pytest.raises(ValueError):
        SpMMConfig(V=1, S=False, W=8, B=True)   # B requires S
    cfg = SpMMConfig(V=2, S=True, W=4, B=True)
    assert cfg.astuple() == (4, cfg.F, 2, True, True)


def test_balanced_capacity_uniform_and_skewed():
    # uniform populations: every candidate quantile is the same value —
    # K is its sublane roundup, one chunk per block
    assert balanced_capacity(np.full(50, 24)) == 24
    assert balanced_capacity(np.array([])) == SUBLANES
    # heavy skew: one 1000-pop block among 100 8-pop blocks must NOT
    # stretch every chunk to 1000 slots
    counts = np.concatenate([[1000], np.full(100, 8)])
    k = balanced_capacity(counts)
    assert k < 1000 and k % SUBLANES == 0


def test_space_includes_balanced_after_uniform():
    space = config_space(64)
    bal = [c for c in space if c.B]
    assert bal and all(c.S for c in bal)
    # B variants come last → exact price ties resolve to uniform configs
    first_bal = next(i for i, c in enumerate(space) if c.B)
    assert all(c.B for c in space[first_bal:])


@pytest.mark.parametrize("case", propcases(
    15, n=integers(16, 80), density=floats(0.02, 0.3),
    v=sampled_from([1, 2]), w=sampled_from([2, 8]),
    seed=integers(0, 1000)), ids=str)
def test_balanced_pcsr_encodes_matrix_property(case):
    """Round-robin balanced packing is a pure steering-array relayout:
    the encoded matrix is bit-identical to the CSR, skew included."""
    rng = np.random.default_rng(case.seed)
    csr, A = random_csr(rng, case.n, case.density, skew=True)
    p = _build(csr, SpMMConfig(V=case.v, S=True, W=case.w, B=True))
    np.testing.assert_allclose(_dense_from_pcsr(p), A, atol=1e-6)
    # grouped trow: all chunks of a block are contiguous (the VMEM
    # revisit/fini machinery needs grouping, not ascending order)
    tr = np.asarray(p.trow)
    starts = {int(t): i for i, t in reversed(list(enumerate(tr)))}
    for b, s in starts.items():
        run = tr[s:s + (tr == b).sum()]
        assert (run == b).all()


def test_balanced_fat_row_splits_many_chunks_near_uniform():
    """A single fat row must shatter into ≥3 chunks and the per-chunk
    occupancy must come out near-uniform (the whole point of B-mode)."""
    n = 256
    rng = np.random.default_rng(3)
    A = (rng.random((n, n)) < 0.02).astype(np.float32)
    A[0] = 1.0                                  # one 256-degree fat row
    A *= rng.standard_normal((n, n)).astype(np.float32)
    A[0, A[0] == 0] = 1.0
    csr = CSRMatrix.from_dense(A)
    p = _build(csr, SpMMConfig(V=1, S=True, W=8, B=True))
    np.testing.assert_allclose(_dense_from_pcsr(p), A, atol=1e-6)
    fat_block = 0                               # row 0 lives in block 0
    n_fat_chunks = int((np.asarray(p.trow) == fat_block).sum())
    assert n_fat_chunks >= 3
    occ = _chunk_pop(p)
    # round-robin packing: occupancy of the fat block's chunks differs
    # by at most 1 vector-slot between any two of them
    fat_occ = occ[np.asarray(p.trow) == fat_block]
    assert fat_occ.max() - fat_occ.min() <= 1
    # and the fat block no longer dictates everyone's capacity
    pu = _build(csr, SpMMConfig(V=1, S=True, W=8))
    assert p.K < pu.K
    assert p.num_slots < pu.num_slots


def test_balanced_reduces_slots_on_skewed_graphs():
    for name, g in [("rmat", rmat(10, 8, seed=1)),
                    ("ba", ba(1000, 4, seed=5))]:
        cfg_u = SpMMConfig(V=1, S=True, W=8)
        cfg_b = SpMMConfig(V=1, S=True, W=8, B=True)
        pu, pb = _build(g, cfg_u), _build(g, cfg_b)
        assert pb.num_slots < pu.num_slots, name
        np.testing.assert_allclose(_dense_from_pcsr(pb).sum(),
                                   _dense_from_pcsr(pu).sum(), rtol=1e-5)


def test_balanced_empty_blocks_and_engine_oracle():
    """Empty row band (whole empty blocks) + skew: engine and Pallas on
    the balanced layout both reproduce the dense product exactly."""
    n = 96
    rng = np.random.default_rng(7)
    A = ((rng.random((n, n)) < 0.1)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[16:48] = 0.0                              # empty blocks
    A[0, :] = rng.standard_normal(n).astype(np.float32)   # fat row
    csr = CSRMatrix.from_dense(A)
    B = rng.standard_normal((n, 20)).astype(np.float32)
    ref = A @ B
    for v in (1, 2):
        p = _build(csr, SpMMConfig(V=v, S=True, W=8 // v, B=True))
        np.testing.assert_allclose(np.asarray(engine_spmm(p, B)), ref,
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(paramspmm(p, jnp.asarray(B))),
                                   ref, atol=1e-4, rtol=1e-4)


def test_balanced_transpose_roundtrip_and_multihead_gat_backward(rng):
    """GAT on a balanced PCSR: the transpose PCSR (itself balanced-built)
    and the slot transfer maps round-trip the layout — multi-head pallas
    forward and flash backward match the engine."""
    n, d, H = 48, 8, 2
    csr, A = random_csr(rng, n, 0.15, skew=True)
    p = _build(csr, SpMMConfig(V=2, S=True, W=4, B=True))
    pt = transpose_pcsr(p)
    np.testing.assert_allclose(_dense_from_pcsr(pt), A.T, atol=1e-6)
    f_eng = make_gat_message_fn(p, backend="engine")
    f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)
    Q = jnp.asarray(rng.standard_normal((H, n, d)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((H, n, d)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((H, n, 6)), jnp.float32)
    np.testing.assert_allclose(np.asarray(f_pal(Q, K, Vf)),
                               np.asarray(f_eng(Q, K, Vf)),
                               atol=1e-4, rtol=1e-4)
    loss = lambda f: lambda q, k, v: (f(q, k, v) ** 2).sum()
    g_eng = jax.grad(loss(f_eng), argnums=(0, 1, 2))(Q, K, Vf)
    g_pal = jax.grad(loss(f_pal), argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(g_eng, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ------------------------------------------------- cost-model selection
def test_cost_model_selects_balanced_on_skew_only():
    dim = 32
    skew_hits = 0
    for spec in corpus("skewed"):
        cm = CostModel(spec.csr)
        best, t_best = cm.best(dim, config_space(dim))
        space_u = [c for c in config_space(dim) if not c.B]
        _, t_uni = cm.best(dim, space_u)
        if spec.family == "uniform" or spec.family == "mesh":
            # uniform-degree controls: B must NOT be selected (exact
            # ties resolve to the uniform config by construction)
            assert not best.B, spec.name
        elif spec.name in ("rmat11", "ba2k"):
            assert best.B, spec.name
            assert t_best < t_uni, spec.name
            skew_hits += 1
    assert skew_hits == 2


def test_oracle_search_head_aware_labels_differ():
    """oracle_search(H=4) must label at least one corpus graph with a
    different best config than H=1 — head tiling shrinks the per-head
    dim and multiplies the grid, so the optimum genuinely moves."""
    dim = 256
    diff = 0
    for spec in corpus("small"):
        r1 = oracle_search(spec.csr, dim, op="gat", H=1)
        r4 = oracle_search(spec.csr, dim, op="gat", H=4)
        if r1.best_config != r4.best_config:
            diff += 1
    assert diff >= 1


def test_oracle_search_measured_accepts_heads():
    g = kregular(256, 8, seed=0)
    space = [SpMMConfig(V=1, S=True, W=8), SpMMConfig(V=1, S=True, W=8, B=True)]
    r = oracle_search(g, 16, space=space, mode="measured", reps=1, H=2)
    assert r.best_config in space
    assert all(np.isfinite(t) for t in r.times.values())


# ----------------------------------------- fully-masked-row regression
def test_fully_masked_rows_gat_forward_and_backward(rng):
    """Rows whose stored edges are ALL masked (zero-valued) have
    rowmax = −inf / rowsum = 0 after the stats kernel — the guards must
    produce exact α = 0, zero output rows, and finite gradients through
    the flash-recompute backward (a NaN-propagating ``maximum(rowsum,
    eps)`` guard fails this)."""
    n = 64
    A = ((rng.random((n, n)) < 0.2)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[A[:, 0] != 0, 0] = 0.0
    rows, cols = np.nonzero(A)
    vals = A[rows, cols].copy()
    masked_rows = np.unique(rows)[::4]           # every 4th nonempty row:
    vals[np.isin(rows, masked_rows)] = 0.0       # ALL its edges masked
    csr = CSRMatrix.from_coo(rows, cols, vals, n, n, sum_duplicates=False)
    Q = jnp.asarray(rng.standard_normal((n, 12)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((n, 12)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    for cfg in (SpMMConfig(V=1, S=True, W=8),
                SpMMConfig(V=2, S=True, W=4, B=True)):
        p = _build(csr, cfg)
        f_eng = make_gat_message_fn(p, backend="engine")
        f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)
        out = np.asarray(f_pal(Q, K, Vf))
        assert np.isfinite(out).all()
        assert (out[masked_rows] == 0).all()
        np.testing.assert_allclose(out, np.asarray(f_eng(Q, K, Vf)),
                                   atol=1e-4, rtol=1e-4)
        loss = lambda f: lambda q, k, v: (f(q, k, v) ** 2).sum()
        g_pal = jax.grad(loss(f_pal), argnums=(0, 1, 2))(Q, K, Vf)
        g_eng = jax.grad(loss(f_eng), argnums=(0, 1, 2))(Q, K, Vf)
        for a, b in zip(g_pal, g_eng):
            assert np.isfinite(np.asarray(a)).all()
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-4)
