import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_csr(rng, n, density=0.05, skew=False):
    from repro.core.sparse import CSRMatrix
    A = (rng.random((n, n)) < density).astype(np.float32)
    if skew:
        heavy = rng.integers(0, n, max(1, n // 20))
        A[heavy] = (rng.random((len(heavy), n)) < 0.5).astype(np.float32)
    A = A * rng.standard_normal((n, n)).astype(np.float32)
    return CSRMatrix.from_dense(A), A
