"""SDDMM engine + Pallas kernel vs dense oracle; fused GAT message grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import edge_softmax, engine_sddmm, make_gat_message_fn
from repro.core.pcsr import SpMMConfig, build_pcsr
from repro.core.sparse import CSRMatrix
from repro.kernels.sddmm import sddmm, sddmm_dense_ref, sddmm_slots_ref

from conftest import random_csr
from _propcheck import booleans, floats, integers, propcases, sampled_from


def _slots_to_dense(p, slots):
    """Scatter a (C, V, K) slot tensor back to dense (n_rows, n_cols)."""
    V, R, K = p.config.V, p.config.R, p.K
    out = np.zeros((p.n_blocks * R, p.n_cols), np.float32)
    for c in range(p.num_chunks):
        for k in range(K):
            base = p.trow[c] * R + p.lrow[c * K + k] * V
            for v in range(V):
                out[base + v, p.colidx[c * K + k]] += slots[c, v, k]
    return out[:p.n_rows]


def _mk(rng, n=67, d=40, density=0.1):
    csr, A = random_csr(rng, n, density)
    Q = rng.standard_normal((n, d)).astype(np.float32)
    K = rng.standard_normal((n, d)).astype(np.float32)
    return csr, A, Q, K


CONFIGS = [SpMMConfig(V=1, S=False, F=1, W=8),
           SpMMConfig(V=2, S=False, F=2, W=4),
           SpMMConfig(V=1, S=True, F=1, W=16),
           SpMMConfig(V=2, S=True, F=1, W=8)]


@pytest.mark.parametrize("cfg", CONFIGS, ids=str)
@pytest.mark.parametrize("backend", ["engine", "pallas"])
def test_sddmm_matches_dense_oracle(rng, cfg, backend):
    csr, A, Q, K = _mk(rng)
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, cfg)
    if backend == "engine":
        slots = np.asarray(engine_sddmm(p, Q, K))
    else:
        slots = np.asarray(sddmm(p, Q, K, interpret=True))
    np.testing.assert_allclose(slots, sddmm_slots_ref(p, Q, K),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(_slots_to_dense(p, slots),
                               sddmm_dense_ref(A, Q, K),
                               atol=1e-5, rtol=1e-5)


def test_sddmm_empty_rows_and_matrix(rng):
    # empty rows: a band of all-zero rows ⇒ no slots, no spurious scores
    A = ((rng.random((64, 64)) < 0.2)
         * rng.standard_normal((64, 64))).astype(np.float32)
    A[8:40] = 0.0
    csr = CSRMatrix.from_dense(A)
    Q = rng.standard_normal((64, 24)).astype(np.float32)
    K = rng.standard_normal((64, 24)).astype(np.float32)
    for cfg in (SpMMConfig(V=2, S=True, W=4), SpMMConfig(V=1, S=False, W=8)):
        p = build_pcsr(csr.indptr, csr.indices, csr.data, 64, 64, cfg)
        for slots in (np.asarray(engine_sddmm(p, Q, K)),
                      np.asarray(sddmm(p, Q, K, interpret=True))):
            np.testing.assert_allclose(_slots_to_dense(p, slots),
                                       sddmm_dense_ref(A, Q, K),
                                       atol=1e-5, rtol=1e-5)

    # fully-empty matrix: degenerate single padding chunk, all-zero scores
    empty = CSRMatrix(np.zeros(11, np.int64), np.zeros(0, np.int64),
                      np.zeros(0, np.float32), 10, 10)
    p = build_pcsr(empty.indptr, empty.indices, empty.data, 10, 10,
                   SpMMConfig())
    Q10 = rng.standard_normal((10, 8)).astype(np.float32)
    assert np.asarray(engine_sddmm(p, Q10, Q10)).sum() == 0.0
    assert np.asarray(sddmm(p, Q10, Q10, interpret=True)).sum() == 0.0


@pytest.mark.parametrize("case", propcases(
    6, n=integers(8, 50), d=sampled_from([8, 40, 130]),
    density=floats(0.02, 0.3), v=sampled_from([1, 2]),
    s=booleans(), seed=integers(0, 99)), ids=str)
def test_sddmm_property(case):
    rng = np.random.default_rng(case.seed)
    csr, A, Q, K = _mk(rng, case.n, case.d, case.density)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, case.n, case.n,
                   SpMMConfig(V=case.v, S=case.s, W=8 // case.v))
    slots = np.asarray(engine_sddmm(p, Q, K))
    np.testing.assert_allclose(_slots_to_dense(p, slots),
                               sddmm_dense_ref(A, Q, K),
                               atol=1e-5, rtol=1e-5)


def test_edge_softmax_rows_sum_to_one(rng):
    csr, A, Q, K = _mk(rng, 50, 16)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 50, 50,
                   SpMMConfig(V=2, S=True, W=4))
    from repro.core.engine import _slot_rows
    arrs = p.to_jax()
    scores = engine_sddmm(p, Q, K)
    mask = arrs["vals"] != 0
    rows = _slot_rows(arrs["lrow"], arrs["trow"],
                      V=2, R=p.config.R, K=p.K)
    alpha = np.asarray(edge_softmax(scores, mask, rows,
                                    p.n_blocks * p.config.R))
    sums = _slots_to_dense(p, alpha).sum(axis=1)
    has_edges = np.diff(csr.indptr) > 0
    np.testing.assert_allclose(sums[has_edges], 1.0, atol=1e-5)
    np.testing.assert_allclose(sums[~has_edges], 0.0, atol=1e-7)
    assert (alpha >= 0).all()


def test_gat_message_backends_agree_with_grads(rng):
    csr, A, Q, K = _mk(rng, 40, 16, 0.15)
    Vf = rng.standard_normal((40, 12)).astype(np.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 40, 40,
                   SpMMConfig(V=2, S=True, W=8))
    f_eng = make_gat_message_fn(p, backend="engine")
    f_pal = make_gat_message_fn(p, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(f_eng(Q, K, Vf)),
                               np.asarray(f_pal(Q, K, Vf)),
                               atol=1e-5, rtol=1e-5)
    loss = lambda f: (lambda q, k, v: (f(q, k, v) ** 2).sum())
    g_eng = jax.grad(loss(f_eng), argnums=(0, 1, 2))(Q, K, Vf)
    g_pal = jax.grad(loss(f_pal), argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(g_eng, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_gat_message_grad_matches_finite_differences(rng):
    """custom_vjp backward vs central differences on a few coordinates."""
    n, d = 20, 6
    csr, A, Q, K = _mk(rng, n, d, 0.25)
    Vf = rng.standard_normal((n, 5)).astype(np.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n,
                   SpMMConfig(V=1, S=False, W=8))
    f = make_gat_message_fn(p, backend="engine")
    w = jnp.asarray(rng.standard_normal(f(Q, K, Vf).shape), jnp.float32)

    def loss(q, k, v):
        return float((f(q, k, v) * w).sum())

    grads = jax.grad(lambda q, k, v: (f(q, k, v) * w).sum(),
                     argnums=(0, 1, 2))(Q, K, Vf)
    eps = 1e-3
    for ai, arr in enumerate((Q, K, Vf)):
        g = np.asarray(grads[ai])
        for (i, j) in [(0, 0), (3, 2), (arr.shape[0] - 1, arr.shape[1] - 1)]:
            up, dn = arr.copy(), arr.copy()
            up[i, j] += eps
            dn[i, j] -= eps
            args_u = [Q, K, Vf]
            args_d = [Q, K, Vf]
            args_u[ai], args_d[ai] = up, dn
            fd = (loss(*args_u) - loss(*args_d)) / (2 * eps)
            np.testing.assert_allclose(g[i, j], fd, atol=5e-2, rtol=5e-2)
