"""Distribution-layer units that don't need 512 devices: sharding rules,
roofline parsers, extrapolation math, host-mesh train/decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.configs.base import SHAPES, ShapeCell, applicable_shapes
from repro.launch import roofline
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import role_pspec
from repro.models import lm


class _FakeMesh:
    def __init__(self, model=16):
        self.shape = {"model": model, "data": 16}
        self.axis_names = ("data", "model")


def test_role_pspec_divisibility_fallbacks():
    m = _FakeMesh()
    # col: last dim divisible
    assert role_pspec("col", (80, 8192, 4096), m) == P(None, None, "model")
    # col falls back to contracting dim (odd heads: hymba 25H→1600 is
    # divisible, whisper qd=384: 384%16=0 too; craft a non-divisible one)
    assert role_pspec("col", (4, 64, 25), m) == P(None, "model", None)
    # both non-divisible → replicate
    assert role_pspec("col", (4, 7, 25), m) == P()
    # expert: E divisible → EP; else feature TP
    assert role_pspec("expert", (24, 32, 64, 512), m) == \
        P(None, "model", None, None)
    assert role_pspec("expert", (32, 40, 1536, 512), m) == \
        P(None, None, None, "model")
    # embed: vocab-parallel
    assert role_pspec("embed", (152064, 8192), m) == P("model", None)


def test_collective_bytes_parser():
    hlo = """
ENTRY %main {
  %ag = bf16[8,128] all-gather(bf16[8,8] %x), replica_groups={}
  %ar = f32[4,4] all-reduce(f32[4,4] %y), to_apply=%sum
  %rs = f32[2,4] reduce-scatter(f32[8,4] %z), dimensions={0}
  %cp = bf16[16] collective-permute(bf16[16] %w)
}
"""
    det = roofline.collective_bytes(hlo)
    assert det["all-gather"] == (1, 8 * 128 * 2)
    assert det["all-reduce"] == (1, 64)
    assert det["reduce-scatter"] == (1, 32)
    assert det["collective-permute"] == (1, 32)


def test_hbm_bytes_fused_parser():
    hlo = """
ENTRY %main {
  %p0 = f32[128,64] parameter(0)
  %c = f32[128,64] convert(f32[128,64] %p0)
  %d = f32[128,128] dot(f32[128,64] %c, f32[64,128] %p1)
  %e = f32[128,128] add(f32[128,128] %d, f32[128,128] %d)
}
"""
    b = roofline.hbm_bytes_fused(hlo)
    # parameter read + dot operands + dot result; convert/add fused
    expect = 128 * 64 * 4 + (128 * 64 * 4 + 64 * 128 * 4 + 128 * 128 * 4)
    assert b == expect


def test_model_flops_accounting():
    cfg = get_config("qwen2-72b")
    total, active = roofline.param_count(cfg)
    assert 70e9 < total < 76e9            # ≈72B
    cfgm = get_config("granite-moe-1b-a400m")
    t2, a2 = roofline.param_count(cfgm)
    assert a2 < t2                        # MoE active < total
    mf = roofline.model_flops_for(cfg, SHAPES["train_4k"])
    assert abs(mf / (6 * active * 4096 * 256) - 1) < 1e-6


def test_applicable_shapes():
    assert "long_500k" in applicable_shapes(get_config("hymba-1.5b"))
    assert "long_500k" in applicable_shapes(get_config("rwkv6-1.6b"))
    assert "long_500k" not in applicable_shapes(get_config("qwen2-72b"))


def test_host_mesh_train_step_runs():
    """The production step builder runs real bytes on the host mesh."""
    from repro.launch import steps
    cfg = get_reduced("chatglm3-6b")
    mesh = make_host_mesh()
    cell = ShapeCell("t", 16, 2, "train")
    fn = steps.jit_train_step(cfg, cell, mesh, chunk=16)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params),
           "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params),
           "step": jnp.zeros((), jnp.int32)}
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    with mesh:
        params, opt, loss = fn(params, opt, batch)
    assert np.isfinite(float(loss))
    from repro.models import sharding_ctx
    sharding_ctx.set_mesh(None)           # don't leak into other tests


def test_linear_extrapolation_math():
    from repro.launch.dryrun import _unflatten_cost, _vec
    base = {"flops": 10.0, "bytes": 100.0, "coll::all-reduce::b": 8.0}
    var = {"flops": 14.0, "bytes": 130.0, "coll::all-reduce::b": 10.0}
    delta = _vec(lambda v, b: v - b, var, base)
    total = _vec(lambda t, d: t + (5 - 1) * d, base, delta)
    out = _unflatten_cost(total)
    assert out["flops"] == 26.0 and out["bytes"] == 220.0
    assert out["coll"]["all-reduce"][1] == 16
