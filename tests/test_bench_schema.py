"""Golden schema of the BENCH_spmm.json perf artifact.

Every emitted row must carry exactly ``name``/``us_per_call``/``derived``
with a machine-parseable ``;``-separated ``k=v`` derived field —
``run.py --json`` validates before writing, this file pins the contract
(and re-validates the ci.sh-generated artifact when one is present —
it is gitignored, so the artifact tests skip on a fresh checkout) so
bench emitters cannot drift back to free-text derived strings.
"""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from benchmarks.common import parse_derived, validate_row

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------- parse_derived
def test_parse_derived_happy_path():
    assert parse_derived("") == {}
    assert parse_derived("a=1") == {"a": "1"}
    assert parse_derived("a=1;b=x2;cv=0.50") == {
        "a": "1", "b": "x2", "cv": "0.50"}
    # values may themselves contain '=' (partition splits on the first)
    assert parse_derived("eq=a=b") == {"eq": "a=b"}
    # trailing separator tolerated
    assert parse_derived("a=1;") == {"a": "1"}


@pytest.mark.parametrize("bad", ["free text", "a=1;notkv", "=v", "a=1;=2"])
def test_parse_derived_rejects_non_kv(bad):
    with pytest.raises(ValueError):
        parse_derived(bad)


# ----------------------------------------------------------- validate_row
def _row(**kw):
    base = {"name": "x/y", "us_per_call": 1.5, "derived": "k=v"}
    base.update(kw)
    return base


def test_validate_row_accepts_golden_row():
    assert validate_row(_row()) == {"k": "v"}
    assert validate_row(_row(derived="")) == {}
    assert validate_row(_row(us_per_call=0)) == {"k": "v"}


def test_validate_row_skipped_requires_null_timing():
    # a skipped row carries NO timing — us_per_call must be JSON null
    d = validate_row(_row(us_per_call=None, derived="skipped=p1_no_halo"))
    assert d["skipped"] == "p1_no_halo"
    # ... and a timing next to a skip annotation is the fake-measurement
    # artifact this schema exists to kill
    with pytest.raises(ValueError, match="skipped"):
        validate_row(_row(us_per_call=42.0, derived="skipped=p1_no_halo"))


@pytest.mark.parametrize("bad", [
    _row(name=""),
    _row(name=3),
    _row(us_per_call="1.5"),
    _row(us_per_call=True),
    _row(us_per_call=float("nan")),
    _row(us_per_call=float("inf")),
    _row(us_per_call=-1.0),
    _row(us_per_call=None),                  # null timing without skipped=
    _row(derived=None),
    _row(derived="free text"),
    {"name": "x", "us_per_call": 1.0},                       # missing key
    _row(extra=1),                                           # extra key
])
def test_validate_row_rejects(bad):
    with pytest.raises(ValueError):
        validate_row(bad)


# ------------------------------------------------- bench_dist overlap row
def test_overlap_row_p1_is_annotated_not_measured():
    """At P=1 there is no halo: the row must carry the skip annotation
    with a NULL timing — neither an on-vs-off 'overlap costs 1.5x'
    artifact nor the off-schedule time masquerading as an overlap
    measurement — the schema regression this file exists for."""
    from benchmarks.bench_dist import overlap_row

    ov = {"skipped": "p1_no_halo", "measured_off_us": 19882.9,
          "overlapped_us": 21000.0, "exchange_us": 0.0}
    name, us, derived = overlap_row("rmat13", 1, ov)
    assert name == "dist/rmat13/p1/overlap"
    assert us is None                 # skipped ⇒ no timing at all
    d = validate_row({"name": name, "us_per_call": us, "derived": derived})
    assert d["skipped"] == "p1_no_halo"
    assert "off_us" not in d          # no fake on/off comparison at P=1


def test_overlap_row_multi_partition_is_measured():
    from benchmarks.bench_dist import overlap_row

    ov = {"measured_on_us": 90.0, "measured_off_us": 120.0,
          "predicted_gain": 1.25, "exchange_us": 10.0,
          "overlapped_us": 95.0}
    name, us, derived = overlap_row("er8k", 4, ov)
    assert name == "dist/er8k/p4/overlap"
    assert us == pytest.approx(90.0)
    d = validate_row({"name": name, "us_per_call": us, "derived": derived})
    assert float(d["off_us"]) == pytest.approx(120.0)
    assert float(d["predicted_gain"]) == pytest.approx(1.25)
    assert "skipped" not in d


# --------------------------------------------------- bench_serve rows
def test_bench_serve_rows_satisfy_schema():
    """A small serving sweep emits schema-clean rows (p50/p99/request)
    with the cache-hit-rate and compiled-bucket count in derived."""
    from benchmarks.bench_serve import _one
    from benchmarks.common import ROWS
    from repro.data.graphs import er

    before = len(ROWS)
    g = er(1500, 5, seed=0)
    metrics = _one("er1k5", g.gcn_normalize(), model="gcn",
                   backend="engine", n_requests=10, seed=0, tick_every=4,
                   feat=8, hidden=16, classes=4)
    new = ROWS[before:]
    assert [n for n, _, _ in new] == [
        "serve/er1k5/gcn/p50", "serve/er1k5/gcn/p99",
        "serve/er1k5/gcn/request"]
    derived = {}
    for name, us, d in new:
        derived[name] = validate_row(
            {"name": name, "us_per_call": us, "derived": d})
        assert us is not None and us > 0
    req = derived["serve/er1k5/gcn/request"]
    assert {"throughput_rps", "hit_rate", "hits", "misses",
            "compiled_buckets"} <= set(req)
    # the structured section run.py folds into BENCH_spmm.json
    assert metrics["requests"] == 10
    assert {"latency_us_p50", "latency_us_p99", "cache_hit_rate",
            "compiled_buckets", "throughput_rps"} <= set(metrics)
    assert metrics["cache_hits"] + metrics["cache_misses"] \
        == metrics["batches"]


def test_bench_serve_registered_in_run_jobs():
    src = (REPO / "benchmarks" / "run.py").read_text()
    assert '"serve": bench_serve.run' in src
    assert '"serve"' in src.split("extras[key] = fn()")[0].rsplit(
        "elif key in", 1)[-1], "serve missing from structured-extras keys"


# ------------------------------------------------ the generated artifact
def test_bench_artifact_satisfies_schema():
    path = REPO / "BENCH_spmm.json"
    if not path.exists():                              # pragma: no cover
        pytest.skip("no BENCH_spmm.json generated yet (run scripts/ci.sh)")
    payload = json.loads(path.read_text())
    assert "rows" in payload and payload["rows"]
    for row in payload["rows"]:
        validate_row(row)


def test_bench_artifact_serve_section():
    """When ci.sh regenerates the artifact with the serve job, the serve
    section must carry the latency/hit-rate columns per run."""
    path = REPO / "BENCH_spmm.json"
    if not path.exists():                              # pragma: no cover
        pytest.skip("no BENCH_spmm.json generated yet (run scripts/ci.sh)")
    payload = json.loads(path.read_text())
    if "serve" not in payload:                         # pragma: no cover
        pytest.skip("artifact predates the serve bench job")
    serve = payload["serve"]
    assert serve["runs"], serve
    for run in serve["runs"]:
        assert {"graph", "model", "backend", "latency_us_p50",
                "latency_us_p99", "throughput_rps", "cache_hit_rate",
                "compiled_buckets"} <= set(run), sorted(run)
        assert run["latency_us_p99"] >= run["latency_us_p50"] > 0
        assert 0.0 <= run["cache_hit_rate"] <= 1.0


def test_bench_artifact_has_no_p1_overlap_artifact():
    """The p1 overlap row, if present, must be the annotated skip — the
    19882.9 µs vs 30487 µs 'overlap hurts' artifact stays dead."""
    path = REPO / "BENCH_spmm.json"
    if not path.exists():                              # pragma: no cover
        pytest.skip("no BENCH_spmm.json generated yet (run scripts/ci.sh)")
    payload = json.loads(path.read_text())
    for row in payload["rows"]:
        if row["name"].endswith("/p1/overlap"):
            d = parse_derived(row["derived"])
            assert d.get("skipped") == "p1_no_halo", row
            assert row["us_per_call"] is None, row
            assert "off_us" not in d, row
