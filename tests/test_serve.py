"""Serving tier (repro.serve) — exactness, soak/replay, cache semantics.

The serving contract under test (docs/SERVING.md):

* **Exactness** — the bucket-padded serving forward equals the
  full-pipeline forward on the same extracted subgraph: bit-equal for
  GCN/GIN with integer-valued data (padding adds exact zeros; integer
  sums are order-free), float-tolerance for GAT (the softmax normalizer
  is summed in layout order), on BOTH backends.
* **Zero recompiles** — after one warm-up per shape bucket, the jitted
  bucket forward never retraces: asserted via the trace-time
  ``serve_recompiles_total`` counter and (pallas) ``pallas_calls_total``.
* **Determinism** — same seeded stream → same batch composition and
  bit-identical outputs.
* **Cache semantics** — distinct buckets never alias, identical buckets
  hit, hit/miss/eviction counters move exactly as scripted.
"""
import numpy as np
import pytest

import repro.obs as obs
from repro.core.pcsr import SUBLANES, SpMMConfig, build_pcsr, pad_pcsr
from repro.core.sparse import CSRMatrix
from repro.data.graphs import er, extract_subgraph, rmat, sample_khop
from repro.serve import (BucketPolicy, GNNService, PackGeom, RequestBatcher,
                         SampledRequest, ShapeBucket, SteeringPackCache,
                         SubgraphRequest, pack_subgraph, reference_forward,
                         replay, synthetic_stream)

from _propcheck import integers, propcases, sampled_from


@pytest.fixture(autouse=True)
def _reset_obs():
    yield
    if obs.trace_enabled():           # pragma: no cover - safety net
        obs.stop_tracing()
    obs.reset_metrics()


def _int_params(params, scale=3.0):
    """Round params to integer values: integer data makes GCN/GIN sums
    exact under any summation order → bit-equality is well-defined."""
    return [{k: np.round(np.asarray(v) * scale) for k, v in l.items()}
            for l in params]


def _int_feats(rng, n, f):
    return rng.integers(0, 3, (n, f)).astype(np.float32)


def _graph(seed, normalize=False):
    g = rmat(10, 6, seed=seed)
    g.data = np.ones_like(g.data)      # integer weights for exactness
    return g.gcn_normalize() if normalize else g


# ------------------------------------------------------------- sampling
def test_sample_khop_deterministic_and_fanout_bounded():
    g = _graph(1)
    seeds = [3, 77, 500]
    a = sample_khop(g, seeds, (4, 2), seed=9)
    b = sample_khop(g, seeds, (4, 2), seed=9)
    assert np.array_equal(a, b)
    assert np.array_equal(a, np.unique(a)), "sorted unique"
    assert set(seeds) <= set(a.tolist()), "seeds always included"
    # hop-1 cap: at most 4 new nodes per seed
    hop1 = sample_khop(g, seeds, (4,), seed=9)
    assert hop1.size <= len(seeds) + 4 * len(seeds)
    # different sampling seed explores a different neighborhood
    c = sample_khop(g, seeds, (4, 2), seed=10)
    full = sample_khop(g, seeds, (10**6, 10**6), seed=0)
    if full.size > a.size:             # capped sampling has freedom
        assert not np.array_equal(a, c) or a.size == full.size


def test_sample_khop_empty_neighborhood_seed():
    # node n-1 is isolated by construction
    base = er(200, 4, seed=3)
    g = CSRMatrix(np.concatenate([base.indptr, [base.indptr[-1]]]),
                  base.indices, base.data, base.n_rows + 1, base.n_cols + 1)
    iso = g.n_rows - 1
    got = sample_khop(g, [iso], (4, 4), seed=0)
    assert np.array_equal(got, [iso])
    sub = extract_subgraph(g, got)
    assert sub.n_rows == 1 and sub.indices.size == 0


@pytest.mark.parametrize("case", propcases(
    4, seed=integers(0, 100), n=integers(20, 200)), ids=str)
def test_extract_subgraph_matches_dense_oracle(case):
    g = er(case.n + 10, 5, seed=case.seed)
    rng = np.random.default_rng(case.seed)
    nodes = np.unique(rng.integers(0, g.n_rows, case.n))
    sub = extract_subgraph(g, nodes)
    ref = g.to_dense()[np.ix_(nodes, nodes)]
    assert np.array_equal(sub.to_dense(), ref)


# ------------------------------------------------------------ pad_pcsr
@pytest.mark.parametrize("case", propcases(
    6,
    seed=integers(0, 1000),
    config=sampled_from([SpMMConfig(V=1, S=False, W=8),
                         SpMMConfig(V=2, S=True, W=8),
                         SpMMConfig(V=1, S=True, W=16, B=True)]),
    n=integers(10, 180)), ids=str)
def test_pad_pcsr_preserves_matrix_and_invariants(case):
    g = er(case.n, 5, seed=case.seed)
    geom = PackGeom.from_bucket(ShapeBucket(256, 2048), case.config)
    padded = pack_subgraph(g, geom)
    # fixed geometry regardless of input
    assert (padded.n_rows, padded.num_chunks, padded.K) == \
        (geom.n_rows, geom.num_chunks, geom.K)
    # exact same matrix in the live corner
    dense = np.zeros((geom.n_rows, geom.n_rows), np.float32)
    from repro.core.pcsr import pcsr_to_coo
    r, c, v = pcsr_to_coo(padded)
    dense[r, c] = v
    assert np.array_equal(dense[:case.n, :case.n], g.to_dense())
    assert not dense[case.n:].any() and not dense[:, case.n:].any()
    # zero empty blocks → covered steering is the identity
    assert padded.n_empty_blocks == 0
    assert padded.covered_num_chunks == padded.num_chunks
    # grouped trow: each block's chunks contiguous, epilogue fires once
    tr = padded.trow
    firsts = tr[np.concatenate([[0], np.flatnonzero(np.diff(tr)) + 1])]
    assert len(firsts) == len(np.unique(firsts))
    assert padded.fini.sum() == len(np.unique(tr)) == geom.n_blocks


def test_pack_shapes_identical_across_different_subgraphs():
    geom = PackGeom.from_bucket(ShapeBucket(256, 2048),
                                SpMMConfig(V=1, S=True, W=8))
    shapes = []
    for seed in (1, 2):
        p = pack_subgraph(er(100 + 40 * seed, 6, seed=seed), geom)
        st = p.steering()
        shapes.append({k: v.shape for k, v in st.items()})
    assert shapes[0] == shapes[1]


def test_build_pcsr_capacity_override():
    g = er(100, 6, seed=0)
    p = build_pcsr(g.indptr, g.indices, g.data, g.n_rows, g.n_cols,
                   SpMMConfig(V=1, S=True, W=8), capacity=40)
    assert p.K == 40                   # already sublane-aligned
    p2 = build_pcsr(g.indptr, g.indices, g.data, g.n_rows, g.n_cols,
                    SpMMConfig(V=1, S=True, W=8), capacity=3)
    assert p2.K == SUBLANES            # rounded up to the sublane quantum


def test_pad_pcsr_rejects_insufficient_budget():
    g = er(60, 6, seed=0)
    cfg = SpMMConfig(V=1, S=True, W=8)
    p = build_pcsr(g.indptr, g.indices, g.data, g.n_rows, g.n_cols, cfg)
    with pytest.raises(ValueError, match="chunk budget"):
        pad_pcsr(p, n_rows=128, num_chunks=1)
    with pytest.raises(ValueError, match="smaller than"):
        pad_pcsr(p, n_rows=16, num_chunks=1000)


def test_pad_pcsr_empty_graph():
    empty = CSRMatrix(np.zeros(33, np.int64), np.zeros(0, np.int64),
                      np.zeros(0, np.float32), 32, 32)
    geom = PackGeom.from_bucket(ShapeBucket(64, 512),
                                SpMMConfig(V=1, S=True, W=8))
    p = pack_subgraph(empty, geom)
    assert p.num_chunks == geom.num_chunks and p.n_empty_blocks == 0


# ----------------------------------------------------------- exactness
def _serve_and_reference(model, backend, *, graph_seed, stream_seed,
                         feat=8, hidden=16, out=4, requests=3,
                         policy=None, atol=0.0):
    import jax
    from repro.models.gnn import init_gat, init_gcn, init_gin

    g = _graph(graph_seed, normalize=False)   # integer weights (1.0)
    rng = np.random.default_rng(graph_seed)
    feats = _int_feats(rng, g.n_rows, feat)
    init = {"gcn": init_gcn, "gin": init_gin, "gat": init_gat}[model]
    params = _int_params(init(jax.random.PRNGKey(0), [feat, hidden, out]),
                         scale=2.0)
    svc = GNNService(g, feats, params, model=model, backend=backend,
                     policy=policy, keep_subgraphs=True)
    stream = synthetic_stream(requests, g.n_rows, seed=stream_seed)
    results = replay(svc, stream, tick_every=2)
    assert len(results) == requests
    for r in results:
        sr = r.sampled
        ref = np.asarray(reference_forward(
            sr.sub, feats[sr.nodes], params, model=model,
            config=r.config, backend=backend))[sr.seed_local]
        if atol == 0.0:
            assert np.array_equal(r.outputs, ref), \
                f"{model}/{backend} request {r.rid} not bit-equal"
        else:
            np.testing.assert_allclose(r.outputs, ref, rtol=0, atol=atol,
                                       err_msg=f"{model}/{backend}/{r.rid}")
    return svc, results


@pytest.mark.parametrize("case", propcases(
    4, _seed=3, graph_seed=integers(0, 50), stream_seed=integers(0, 50),
    model=sampled_from(["gcn", "gin", "gat"])), ids=str)
def test_serve_exactness_engine_property(case):
    atol = 1e-5 if case.model == "gat" else 0.0
    _serve_and_reference(case.model, "engine", graph_seed=case.graph_seed,
                         stream_seed=case.stream_seed, atol=atol)


@pytest.mark.parametrize("model", ["gcn", "gat"])
def test_serve_exactness_pallas(model):
    pol = BucketPolicy([ShapeBucket(256, 2048)])
    atol = 1e-5 if model == "gat" else 0.0
    _serve_and_reference(model, "pallas", graph_seed=5, stream_seed=7,
                         requests=2, policy=pol, atol=atol)


def test_serve_exactness_empty_neighborhood_seed():
    import jax
    from repro.models.gnn import init_gcn

    base = _graph(2)
    g = CSRMatrix(np.concatenate([base.indptr, [base.indptr[-1]]]),
                  base.indices, base.data, base.n_rows + 1, base.n_cols + 1)
    iso = g.n_rows - 1
    feats = _int_feats(np.random.default_rng(0), g.n_rows, 8)
    params = _int_params(init_gcn(jax.random.PRNGKey(0), [8, 16, 4]))
    svc = GNNService(g, feats, params, model="gcn", keep_subgraphs=True)
    res = replay(svc, [SubgraphRequest("iso", (iso,), (4, 2), 1),
                       SubgraphRequest("mix", (iso, 3), (4,), 2)],
                 tick_every=1)
    for r in res:
        sr = r.sampled
        ref = np.asarray(reference_forward(
            sr.sub, feats[sr.nodes], params, model="gcn",
            config=r.config))[sr.seed_local]
        assert np.array_equal(r.outputs, ref)
    # the isolated seed aggregates nothing: output = bias path only
    assert res[0].outputs.shape == (1, 4)


def test_serve_exactness_bucket_ceiling_exact_size():
    """A batch landing EXACTLY on the node ceiling still packs (the +R
    headroom block hosts the filler chunks) and stays exact."""
    import jax
    from repro.models.gnn import init_gcn

    g = _graph(4)
    # find a request whose subgraph is then padded to exactly n_ceil
    nodes = sample_khop(g, [1, 2, 3], (8, 8), seed=1)
    pol = BucketPolicy([ShapeBucket(int(nodes.size), 4096)])
    feats = _int_feats(np.random.default_rng(1), g.n_rows, 8)
    params = _int_params(init_gcn(jax.random.PRNGKey(1), [8, 16, 4]))
    svc = GNNService(g, feats, params, model="gcn", policy=pol,
                     keep_subgraphs=True)
    res = replay(svc, [SubgraphRequest("edge", (1, 2, 3), (8, 8), 1)],
                 tick_every=1)
    sr = res[0].sampled
    assert sr.n == pol.largest.n_ceil            # ceiling-exact
    ref = np.asarray(reference_forward(
        sr.sub, feats[sr.nodes], params, model="gcn",
        config=res[0].config))[sr.seed_local]
    assert np.array_equal(res[0].outputs, ref)


# ---------------------------------------------------------- soak/replay
def _recompile_total(snap):
    return sum(snap.get("serve_recompiles_total", {}).values())


def test_soak_replay_deterministic_and_zero_recompiles():
    """Seeded bursty stream, twice: identical batch composition, bit-
    identical outputs, and — via the trace-time recompile counter — one
    compilation per bucket on warm-up, ZERO for the rest of the run."""
    import jax
    from repro.models.gnn import init_gcn

    g = _graph(6)
    feats = _int_feats(np.random.default_rng(2), g.n_rows, 24)
    # distinctive dims → this test owns its jit cache entries
    params = _int_params(init_gcn(jax.random.PRNGKey(2), [24, 40, 6]))
    pol = BucketPolicy([ShapeBucket(256, 2048), ShapeBucket(512, 4096),
                        ShapeBucket(1024, 8192)])
    stream = synthetic_stream(24, g.n_rows, seed=13)

    with obs.tracing():
        svc1 = GNNService(g, feats, params, model="gcn", policy=pol)
        out1 = replay(svc1, stream, tick_every=4)
        warm = _recompile_total(obs.metrics_snapshot())
        buckets_used = {b for b, _ in svc1.batch_log}
        assert warm == svc1.compiled_buckets == len(buckets_used) > 0
        # the REST of the stream (after each bucket's first batch) plus a
        # full second pass recompiled nothing
        svc2 = GNNService(g, feats, params, model="gcn", policy=pol)
        out2 = replay(svc2, stream, tick_every=4)
        assert _recompile_total(obs.metrics_snapshot()) == warm, \
            "recompilation after warm-up"

    assert svc1.batch_log == svc2.batch_log, "batch composition drifted"
    for a, b in zip(out1, out2):
        assert a.rid == b.rid and a.bucket_key == b.bucket_key
        assert np.array_equal(a.outputs, b.outputs)


def test_soak_pallas_calls_flat_after_warmup():
    """Pallas backend: ``pallas_calls_total`` increments at trace time
    only, so a flat counter across a replayed stream proves the kernels
    compiled once per bucket."""
    import jax
    from repro.models.gnn import init_gcn

    g = rmat(9, 5, seed=8)
    g.data = np.ones_like(g.data)
    feats = _int_feats(np.random.default_rng(3), g.n_rows, 8)
    params = _int_params(init_gcn(jax.random.PRNGKey(3), [8, 16, 4]))
    pol = BucketPolicy([ShapeBucket(128, 1024)])
    stream = synthetic_stream(4, g.n_rows, seed=17)

    def pallas_total():
        snap = obs.metrics_snapshot()
        return sum(snap.get("pallas_calls_total", {}).values())

    with obs.tracing():
        svc = GNNService(g, feats, params, model="gcn", backend="pallas",
                         policy=pol)
        replay(svc, stream, tick_every=2)
        warm = pallas_total()
        svc2 = GNNService(g, feats, params, model="gcn", backend="pallas",
                          policy=pol)
        replay(svc2, stream, tick_every=2)
        assert pallas_total() == warm, "pallas kernels re-traced"


# ------------------------------------------------------ cache semantics
def test_cache_scripted_hits_misses_and_no_aliasing():
    a, b = ShapeBucket(128, 512), ShapeBucket(256, 1024)
    g = er(100, 5, seed=0)
    with obs.tracing():
        cache = SteeringPackCache(dim=16, capacity=4)
        pa1 = cache.get(a, g)
        pa2 = cache.get(a, g)
        pb = cache.get(b, g)
        snap = obs.metrics_snapshot()
    assert (cache.hits, cache.misses, cache.evictions) == (1, 2, 0)
    assert pa1 is pa2, "identical buckets must hit"
    assert pa1.geom != pb.geom, "distinct buckets must never alias"
    assert snap["serve_cache_hits_total"] == {f"bucket={a.key}": 1.0}
    assert snap["serve_cache_misses_total"] == {f"bucket={a.key}": 1.0,
                                                f"bucket={b.key}": 1.0}
    assert cache.hit_rate == pytest.approx(1 / 3)


def test_cache_capacity_bounded_eviction():
    a, b = ShapeBucket(128, 512), ShapeBucket(256, 1024)
    g = er(80, 5, seed=1)
    with obs.tracing():
        cache = SteeringPackCache(dim=16, capacity=1)
        cache.get(a, g)
        cache.get(b, g)                # evicts a
        cache.get(a, g)                # miss again, evicts b
        snap = obs.metrics_snapshot()
    assert (cache.hits, cache.misses, cache.evictions) == (0, 3, 2)
    assert len(cache) == 1
    assert sum(snap["serve_cache_evictions_total"].values()) == 2


# ------------------------------------------------------------- batching
def _fake_sampled(rid, n, e):
    rows = np.zeros(e, np.int64)
    cols = np.arange(e) % max(n, 1)
    sub = CSRMatrix.from_coo(rows, cols, np.ones(e, np.float32), n, n,
                             sum_duplicates=False)
    return SampledRequest(SubgraphRequest(rid, (0,), (1,)),
                          np.arange(n), sub, np.zeros(1, np.int64))


def test_batcher_greedy_fifo_composition():
    bat = RequestBatcher(n_max=100, e_max=1000, max_batch=3)
    for i, n in enumerate([40, 40, 40, 10, 10, 10, 10, 90]):
        bat.add(_fake_sampled(f"r{i}", n, 5))
    groups = [[sr.req.rid for sr in b] for b in bat.drain()]
    assert groups == [["r0", "r1"], ["r2", "r3", "r4"],
                      ["r5", "r6"], ["r7"]]
    assert len(bat) == 0


def test_batcher_rejects_oversize_request():
    bat = RequestBatcher(n_max=50, e_max=100)
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        bat.add(_fake_sampled("big", 60, 5))


def test_synthetic_stream_deterministic():
    s1 = synthetic_stream(10, 1000, seed=4)
    s2 = synthetic_stream(10, 1000, seed=4)
    assert s1 == s2
    assert [r.rid for r in s1] == [f"r{i}" for i in range(10)]
    assert all(s1[i].arrival_s <= s1[i + 1].arrival_s
               for i in range(len(s1) - 1))
    assert synthetic_stream(10, 1000, seed=5) != s1
