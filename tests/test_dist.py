"""Distributed graph subsystem (repro.dist).

Two tiers in one module:

* host-side partition/halo property tests — run on any device count;
* mesh execution tests (dist_spmm / dist_gat_message vs the
  single-device engine, fwd + grads) — need ≥ 2 devices, which CPU hosts
  only have under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (scripts/ci.sh runs this module that way in its own process).
"""
import numpy as np
import pytest

import _propcheck as pc
from conftest import random_csr

import jax
import jax.numpy as jnp

from repro.core import (CostModel, CSRMatrix, SpMMConfig, build_pcsr,
                        config_space, extract_features, transpose_pcsr)
from repro.core.engine import engine_spmm, make_gat_message_fn, make_spmm_fn
from repro.data.graphs import er, grid2d, rmat, sbm
from repro.dist import (DistGraph, build_halo, dist_gat_message, dist_spmm,
                        partition_bounds, partition_csr, split_local_halo,
                        unpartition_rows)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


def _graph(kind, seed):
    if kind == "rmat":
        return rmat(9, 8, seed=seed)
    if kind == "er":
        return er(700, 6, seed=seed)
    if kind == "grid":
        return grid2d(26, seed=seed)
    return sbm(6, 96, 0.2, 1.0, seed=seed)


def _global_coo(csr):
    rows = np.repeat(np.arange(csr.n_rows), csr.degrees)
    return set(zip(rows.tolist(), csr.indices.tolist(),
                   np.round(csr.data, 5).tolist()))


# ------------------------------------------------- partition invariants
@pytest.mark.parametrize("case", pc.propcases(
    12, kind=pc.sampled_from(["rmat", "er", "grid", "sbm"]),
    n_parts=pc.integers(1, 7),
    strategy=pc.sampled_from(["contiguous", "balanced"]),
    seed=pc.integers(0, 10**6)), ids=str)
def test_partition_covers_every_nnz_exactly_once(case):
    csr = _graph(case.kind, case.seed)
    part = partition_csr(csr, case.n_parts, case.strategy)
    # shard nnz counts sum to the global nnz
    assert sum(s.csr.nnz for s in part.shards) == csr.nnz
    # and the union of shard edge sets, mapped back to global ids,
    # reproduces the original edge set exactly (values included)
    rebuilt = set()
    for s in part.shards:
        rows = np.repeat(np.arange(s.csr.n_rows), s.csr.degrees) + s.start
        cols = s.csr.indices.copy()
        local = cols < part.rows_pad
        assert np.all(rows < s.stop), "edge scattered outside its shard"
        cols = np.where(local, cols + s.start,
                        -1 if s.n_halo == 0 else
                        s.halo_global[np.clip(cols - part.rows_pad, 0,
                                              max(0, s.n_halo - 1))])
        # halo references must stay inside the true halo range
        assert np.all(s.csr.indices[~local] - part.rows_pad < s.n_halo)
        rebuilt |= set(zip(rows.tolist(), cols.tolist(),
                           np.round(s.csr.data, 5).tolist()))
    assert rebuilt == _global_coo(csr)


@pytest.mark.parametrize("case", pc.propcases(
    8, kind=pc.sampled_from(["rmat", "er", "sbm"]),
    n_parts=pc.integers(2, 6),
    seed=pc.integers(0, 10**6)), ids=str)
def test_halo_maps_are_consistent(case):
    csr = _graph(case.kind, case.seed)
    part = partition_csr(csr, case.n_parts, "balanced")
    halo = build_halo(part)
    for p, s in enumerate(part.shards):
        assert halo.n_halo[p] == s.n_halo
        # halo columns are foreign, sorted, unique
        own = part.owner(s.halo_global)
        assert np.all(own != p)
        assert np.all(np.diff(s.halo_global) > 0)
        # each halo entry's flat gathered position points at a send slot
        # of the owner that holds exactly that global row
        for h in range(s.n_halo):
            flat = int(halo.halo_src[p, h])
            q, slot = divmod(flat, halo.max_send)
            assert q == own[h] and slot < halo.n_send[q]
            g = int(halo.send_idx[q, slot]) + int(part.starts[q])
            assert g == int(s.halo_global[h])


def test_balanced_strategy_bounds_shard_nnz():
    csr = rmat(11, 8, seed=3)          # power-law: contiguous is skewed
    part = partition_csr(csr, 4, "balanced")
    target = csr.nnz / 4
    slack = int(csr.degrees.max())
    for s in part.shards:
        assert s.csr.nnz <= target + slack


def test_pad_position_roundtrip():
    csr = er(311, 5, seed=9)           # odd n: shards pad unevenly
    part = partition_csr(csr, 3, "contiguous")
    x = np.arange(csr.n_rows)
    stacked = np.zeros(part.n_parts * part.rows_pad, np.int64)
    stacked[part.pad_position(x)] = x
    assert np.array_equal(unpartition_rows(part, stacked), x)


def test_partition_rejects_bad_inputs():
    csr = er(64, 4, seed=0)
    with pytest.raises(ValueError):
        partition_bounds(csr, 0)
    with pytest.raises(ValueError):
        partition_bounds(csr, 4, "zigzag")
    rect = CSRMatrix(np.array([0, 1]), np.array([2]),
                     np.ones(1, np.float32), 1, 8)
    with pytest.raises(ValueError):
        partition_csr(rect, 2)


def test_distgraph_plan_is_device_free():
    """Constructing a DistGraph is a host-side plan: partitioning and
    per-shard config selection must work for more partitions than the
    host has devices (the mesh is only resolved on first call)."""
    csr = rmat(8, 6, seed=4)
    n_parts = jax.device_count() + 3
    g = DistGraph(csr, 16, n_parts, strategy="balanced")
    assert len(g.configs) == n_parts
    assert len(g.predicted_times) == n_parts
    with pytest.raises(ValueError, match="devices"):
        _ = g.mesh


def test_core_package_exports():
    # the satellite: downstream code imports repro.core, not submodules
    assert SpMMConfig(V=2, W=4).R == 8
    assert callable(build_pcsr) and callable(transpose_pcsr)
    assert callable(extract_features) and callable(CostModel)
    assert len(config_space(64)) > 0


# ------------------------------------------------------ mesh execution
def _dist_tol(a, b):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)


@needs_mesh
@pytest.mark.parametrize("case", pc.propcases(
    6, kind=pc.sampled_from(["rmat", "er", "grid", "sbm"]),
    n_parts=pc.sampled_from([2, 4]),
    strategy=pc.sampled_from(["contiguous", "balanced"]),
    seed=pc.integers(0, 10**6)), ids=str)
def test_dist_spmm_matches_engine(case):
    csr = _graph(case.kind, case.seed)
    dim = 32
    rng = np.random.default_rng(case.seed)
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    cfg, _ = CostModel(csr).best(dim, config_space(dim))
    ref = engine_spmm(build_pcsr(csr.indptr, csr.indices, csr.data,
                                 csr.n_rows, csr.n_cols, cfg), B)
    g = DistGraph(csr, dim, case.n_parts, strategy=case.strategy)
    _dist_tol(dist_spmm(g, B), ref)


@needs_mesh
def test_dist_spmm_grad_matches_transpose_path():
    csr = rmat(9, 8, seed=5)
    dim = 24
    rng = np.random.default_rng(1)
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    cfg, _ = CostModel(csr).best(dim, config_space(dim))
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, cfg)
    t = csr.transpose()
    pt = build_pcsr(t.indptr, t.indices, t.data, t.n_rows, t.n_cols, cfg)
    ref_fn = make_spmm_fn(p, pt)
    g = DistGraph(csr, dim, 4, strategy="balanced")
    gd = jax.grad(lambda b: (dist_spmm(g, b) ** 2).sum())(B)
    gr = jax.grad(lambda b: (ref_fn(b) ** 2).sum())(B)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gr),
                               rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_gat_message_matches_engine_fwd_and_grads():
    csr = sbm(5, 64, 0.25, 1.0, seed=7)
    rng = np.random.default_rng(2)
    n = csr.n_rows
    Q = jnp.asarray(rng.standard_normal((n, 16)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((n, 16)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((n, 20)), jnp.float32)
    cfg, _ = CostModel(csr).best(16, config_space(16), op="gat")
    p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n, cfg)
    ref_fn = make_gat_message_fn(p)
    g = DistGraph(csr, 16, 3, strategy="contiguous", op="gat")
    _dist_tol(dist_gat_message(g, Q, K, Vf), ref_fn(Q, K, Vf))
    loss_d = lambda q, k, v: (dist_gat_message(g, q, k, v) ** 2).sum()
    loss_r = lambda q, k, v: (ref_fn(q, k, v) ** 2).sum()
    gd = jax.grad(loss_d, argnums=(0, 1, 2))(Q, K, Vf)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(gd, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_spmm_pallas_backend():
    csr = rmat(7, 6, seed=2)           # tiny: interpret-mode kernels
    dim = 16
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    cfg, _ = CostModel(csr).best(dim, config_space(dim))
    ref = engine_spmm(build_pcsr(csr.indptr, csr.indices, csr.data,
                                 csr.n_rows, csr.n_cols, cfg), B)
    g = DistGraph(csr, dim, 2, backend="pallas", interpret=True)
    _dist_tol(dist_spmm(g, B), ref)
    gd = jax.grad(lambda b: (dist_spmm(g, b) ** 2).sum())(B)
    ge = jax.grad(lambda b: (engine_spmm(
        build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, cfg), b) ** 2).sum())(B)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(ge),
                               rtol=2e-3, atol=2e-4)


@needs_mesh
def test_per_partition_configs_adapt_on_powerlaw():
    """The cross-shard adaptivity claim: a power-law graph's balanced
    shards have different density/CV, so the cost model picks different
    ⟨W,F,V,S⟩ per shard — and the result still matches single-device."""
    csr = rmat(10, 8, seed=1)
    dim = 32
    g = DistGraph(csr, dim, 4, strategy="balanced")
    assert len(set(g.configs)) > 1, [c.astuple() for c in g.configs]
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    cfg, _ = CostModel(csr).best(dim, config_space(dim))
    ref = engine_spmm(build_pcsr(csr.indptr, csr.indices, csr.data,
                                 csr.n_rows, csr.n_cols, cfg), B)
    _dist_tol(dist_spmm(g, B), ref)


@needs_mesh
def test_dist_handles_random_matrices_and_explicit_configs(rng):
    csr, dense = random_csr(rng, 150, density=0.08, skew=True)
    B = jnp.asarray(rng.standard_normal((150, 16)), jnp.float32)
    g = DistGraph(csr, 16, 2, configs=SpMMConfig(V=1, W=8, F=1, S=True))
    assert all(c == SpMMConfig(V=1, W=8, F=1, S=True) for c in g.configs)
    np.testing.assert_allclose(np.asarray(dist_spmm(g, B)),
                               dense @ np.asarray(B),
                               rtol=2e-3, atol=2e-3)


@needs_mesh
@pytest.mark.parametrize("backend", ["engine", "pallas"])
def test_dist_fused_epilogue_matches_dense(backend, rng):
    """DistGraph.fused = act(scale ⊙ (A·B) + bias) with the epilogue
    applied per shard inside the SPMD program — forward vs dense and
    grads (B, bias) vs the single-device fused operator."""
    from repro.core.engine import ParamSpMMOperator

    csr, dense = random_csr(rng, 96, density=0.1, skew=True)
    dim = 12
    B = jnp.asarray(rng.standard_normal((96, dim)), jnp.float32)
    sc = jnp.asarray(rng.random(96) + 0.5, jnp.float32)
    b = jnp.asarray(rng.standard_normal(dim), jnp.float32)
    g = DistGraph(csr, dim, 2, backend=backend, interpret=True)
    out = np.asarray(g.fused(B, scale=sc, bias=b, activation="relu"))
    ref = np.maximum(np.asarray(sc)[:, None] * (dense @ np.asarray(B))
                     + np.asarray(b), 0.0)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    def loss(fused):
        return lambda B, b: (fused(B, scale=sc, bias=b,
                                   activation="relu") ** 2).sum()

    gd = jax.grad(loss(g.fused), (0, 1))(B, b)
    cfg, _ = CostModel(csr).best(dim, config_space(dim))
    op = ParamSpMMOperator(csr, cfg, backend="engine")
    ge = jax.grad(loss(op.fused), (0, 1))(B, b)
    for a, c in zip(gd, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-3, atol=2e-3)


@needs_mesh
def test_dist_train_gnn_partitions():
    from repro.apps.gnn import train_gnn
    from repro.data.tasks import community_task

    task = community_task(n_blocks=4, block_size=48, seed=0)
    res = train_gnn(task, model="gcn", hidden=32, n_layers=2, steps=8,
                    partitions=2)
    assert isinstance(res.config, list) and len(res.config) == 2
    assert res.losses[-1] < res.losses[0]


# ------------------------------------------- multi-head distributed GAT
def _mh_operands(rng, n, H, da, dv):
    Q = jnp.asarray(rng.standard_normal((H, n, da)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((H, n, da)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((H, n, dv)), jnp.float32)
    return Q, K, Vf


def _gat_ref(csr, H, dim):
    cfg, _ = CostModel(csr).best(dim, config_space(dim), op="gat", H=H)
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_rows, cfg)
    return make_gat_message_fn(p)


@needs_mesh
@pytest.mark.parametrize("backend", ["engine", "pallas"])
def test_dist_gat_multihead_matches_engine(backend):
    """Multi-head distributed GAT — fwd and grads vs the single-device
    engine, on both backends (the Pallas backend runs the two-kernel
    fused forward + all-Pallas backward per shard)."""
    csr = sbm(5, 64, 0.25, 1.0, seed=7)
    rng = np.random.default_rng(2)
    H = 2
    Q, K, Vf = _mh_operands(rng, csr.n_rows, H, 16, 20)
    ref_fn = _gat_ref(csr, H, 16)
    g = DistGraph(csr, 16, 3, strategy="balanced", op="gat", heads=H,
                  backend=backend, interpret=True)
    _dist_tol(dist_gat_message(g, Q, K, Vf), ref_fn(Q, K, Vf))
    loss_d = lambda q, k, v: (dist_gat_message(g, q, k, v) ** 2).sum()
    loss_r = lambda q, k, v: (ref_fn(q, k, v) ** 2).sum()
    gd = jax.grad(loss_d, argnums=(0, 1, 2))(Q, K, Vf)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(gd, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_gat_multihead_empty_shard_pallas():
    """A fully-empty shard (zero local nnz → degenerate PCSR) must ride
    the same head-tiled two-kernel program as its loaded neighbours."""
    rng = np.random.default_rng(4)
    n, P = 96, 4
    A = ((rng.random((n, n)) < 0.12)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[24:48] = 0.0                     # shard 1 of a 4-way contiguous
    csr = CSRMatrix.from_dense(A)      # split owns no edges at all
    H = 3
    Q, K, Vf = _mh_operands(rng, n, H, 8, 12)
    ref_fn = _gat_ref(csr, H, 8)
    g = DistGraph(csr, 8, P, strategy="contiguous", op="gat", heads=H,
                  backend="pallas", interpret=True)
    assert any(s.csr.nnz == 0 for s in g.part.shards)
    _dist_tol(dist_gat_message(g, Q, K, Vf), ref_fn(Q, K, Vf))
    gd = jax.grad(lambda q, k, v:
                  (dist_gat_message(g, q, k, v) ** 2).sum(),
                  argnums=(0, 1, 2))(Q, K, Vf)
    gr = jax.grad(lambda q, k, v: (ref_fn(q, k, v) ** 2).sum(),
                  argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(gd, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_gat_multihead_halo_heavy_pallas():
    """Halo-heavy partitions (ER graph: most sources are remote) — the
    joint K/Vf exchange and the dK/dVf scatter-back carry most of the
    gradient mass."""
    csr = er(120, 12, seed=3)
    rng = np.random.default_rng(5)
    H = 2
    Q, K, Vf = _mh_operands(rng, csr.n_rows, H, 8, 8)
    ref_fn = _gat_ref(csr, H, 8)
    g = DistGraph(csr, 8, 3, strategy="balanced", op="gat", heads=H,
                  backend="pallas", interpret=True)
    assert max(s.n_halo for s in g.part.shards) > 40   # genuinely heavy
    _dist_tol(dist_gat_message(g, Q, K, Vf), ref_fn(Q, K, Vf))
    gd = jax.grad(lambda q, k, v:
                  (dist_gat_message(g, q, k, v) ** 2).sum(),
                  argnums=(0, 1, 2))(Q, K, Vf)
    gr = jax.grad(lambda q, k, v: (ref_fn(q, k, v) ** 2).sum(),
                  argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(gd, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_gat_pallas_forward_is_two_kernels_per_shard(monkeypatch):
    """The acceptance bar: the distributed multi-head GAT forward
    launches exactly TWO Pallas kernels per shard — the fused
    SDDMM→softmax-stats kernel and the prologue SpMM — with no
    interstitial elementwise pass (α never materializes)."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import count_pallas_calls

    csr = sbm(5, 64, 0.25, 1.0, seed=7)
    rng = np.random.default_rng(2)
    H, P = 2, 3
    Q, K, Vf = _mh_operands(rng, csr.n_rows, H, 16, 20)
    g = DistGraph(csr, 16, P, strategy="contiguous", op="gat", heads=H,
                  backend="pallas", interpret=True)
    calls = count_pallas_calls(lambda: dist_gat_message(g, Q, K, Vf))
    assert len(calls) == 2 * P, calls
    assert sum("sddmm_softmax" in c for c in calls) == P
    assert sum("_pro" in c for c in calls) == P   # prologue-fused SpMM


@needs_mesh
def test_dist_gat_pallas_backward_no_engine_fallback(monkeypatch):
    """The distributed GAT backward is dedicated all-Pallas: grads must
    come out with every engine path stubbed to raise."""
    import repro.core.engine as emod
    import repro.dist.gat as gmod

    csr = sbm(5, 64, 0.25, 1.0, seed=7)
    rng = np.random.default_rng(2)
    H = 2
    Q, K, Vf = _mh_operands(rng, csr.n_rows, H, 16, 20)
    ref_fn = _gat_ref(csr, H, 16)
    gr = jax.grad(lambda q, k, v: (ref_fn(q, k, v) ** 2).sum(),
                  argnums=(0, 1, 2))(Q, K, Vf)

    def _boom(*a, **kw):
        raise AssertionError("engine fallback in the dist Pallas GAT path")

    for mod in (emod, gmod):
        monkeypatch.setattr(mod, "_engine", _boom)
        monkeypatch.setattr(mod, "_engine_sddmm", _boom)
    monkeypatch.setattr(gmod, "attend_scores", _boom)
    monkeypatch.setattr(emod, "edge_softmax", _boom)
    g = DistGraph(csr, 16, 3, strategy="balanced", op="gat", heads=H,
                  backend="pallas", interpret=True)
    gd = jax.grad(lambda q, k, v:
                  (dist_gat_message(g, q, k, v) ** 2).sum(),
                  argnums=(0, 1, 2))(Q, K, Vf)
    for a, b in zip(gd, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_distgraph_heads_aware_per_shard_configs():
    """Device-free plan check: the per-shard configs are priced for the
    head count — head tiling shrinks the per-head lane width, so the
    shard optima at H=8 differ from H=1 (the dist analogue of the
    head-aware cost-model regression in test_fusion)."""
    rng = np.random.default_rng(0)
    n = 1500
    A = rng.random((n, n)) < 0.004
    rows, cols = np.nonzero(A)
    csr = CSRMatrix.from_coo(rows, cols, np.ones(len(rows), np.float32),
                             n, n)
    g1 = DistGraph(csr, 512, 2, strategy="balanced", op="gat", heads=1)
    g8 = DistGraph(csr, 512, 2, strategy="balanced", op="gat", heads=8)
    assert [c.astuple() for c in g1.configs] \
        != [c.astuple() for c in g8.configs]


# --------------------------------------------------- halo/compute overlap
def test_split_local_halo_partitions_every_edge():
    csr = rmat(9, 8, seed=11)
    part = partition_csr(csr, 4, "balanced")
    for s in part.shards:
        loc, hal = split_local_halo(s, part)
        assert loc.nnz + hal.nnz == s.csr.nnz
        assert loc.n_cols == part.rows_pad and hal.n_cols == part.halo_pad
        if hal.nnz:
            assert hal.indices.max() < s.n_halo


@needs_mesh
@pytest.mark.parametrize("case", pc.propcases(
    4, kind=pc.sampled_from(["rmat", "er", "grid", "sbm"]),
    n_parts=pc.sampled_from([2, 4]),
    backend=pc.sampled_from(["engine", "pallas"]),
    seed=pc.integers(0, 10**6)), ids=str)
def test_dist_spmm_overlap_matches_nonoverlap(case):
    """The overlap decomposition (local + halo sub-SpMMs, gather hidden
    behind the local one) is a pure schedule change: forward and
    backward must match the serialized path numerically."""
    csr = _graph(case.kind, case.seed)
    dim = 16
    rng = np.random.default_rng(case.seed)
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    g0 = DistGraph(csr, dim, case.n_parts, strategy="balanced",
                   backend=case.backend, interpret=True)
    g1 = DistGraph(csr, dim, case.n_parts, strategy="balanced",
                   backend=case.backend, interpret=True, overlap=True)
    _dist_tol(dist_spmm(g1, B), dist_spmm(g0, B))
    gd0 = jax.grad(lambda b: (dist_spmm(g0, b) ** 2).sum())(B)
    gd1 = jax.grad(lambda b: (dist_spmm(g1, b) ** 2).sum())(B)
    np.testing.assert_allclose(np.asarray(gd1), np.asarray(gd0),
                               rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_fused_overlap_matches_nonoverlap(rng):
    """Fused epilogue under overlap: applied per shard after the
    local+halo add — same numbers as the in-branch epilogue path."""
    csr = rmat(9, 8, seed=5)
    dim = 12
    n = csr.n_rows
    B = jnp.asarray(rng.standard_normal((n, dim)), jnp.float32)
    sc = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    b = jnp.asarray(rng.standard_normal(dim), jnp.float32)
    g0 = DistGraph(csr, dim, 4, strategy="balanced")
    g1 = DistGraph(csr, dim, 4, strategy="balanced", overlap=True)
    _dist_tol(g1.fused(B, scale=sc, bias=b, activation="relu"),
              g0.fused(B, scale=sc, bias=b, activation="relu"))

    def loss(g):
        return lambda B, b: (g.fused(B, scale=sc, bias=b,
                                     activation="relu") ** 2).sum()

    gd0 = jax.grad(loss(g0), (0, 1))(B, b)
    gd1 = jax.grad(loss(g1), (0, 1))(B, b)
    for a, c in zip(gd1, gd0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_overlap_adapts_subconfigs():
    """Overlap mode selects configs per *sub-matrix*: on a power-law
    graph the halo part is sparser/more scattered than the local part,
    so at least one shard picks different ⟨W,F,V,S⟩ for the two."""
    csr = rmat(10, 8, seed=1)
    g = DistGraph(csr, 32, 4, strategy="balanced", overlap=True)
    assert len(g.overlap_configs) == 4
    assert any(lc != hc for lc, hc in g.overlap_configs), \
        [(lc.astuple(), hc.astuple()) for lc, hc in g.overlap_configs]


# --------------------------------------------- fused backward dbias fold
@needs_mesh
def test_dist_fused_dbias_reduced_inside_spmd(rng):
    """The PR-4 leftover, fixed: dbias comes out of the SAME shard_map
    program as dB (an in-program psum), not a global reduce outside the
    SPMD program — and it is exactly Σ_rows of the epilogue gradient."""
    from repro.core.engine import epilogue_grad

    csr, dense = random_csr(rng, 96, density=0.1, skew=True)
    dim = 12
    B = jnp.asarray(rng.standard_normal((96, dim)), jnp.float32)
    sc = jnp.asarray(rng.random(96) + 0.5, jnp.float32)
    b = jnp.asarray(rng.standard_normal(dim), jnp.float32)
    g = DistGraph(csr, dim, 2)
    out = g.fused(B, scale=sc, bias=b, activation="relu")
    dOut = jnp.ones_like(out)
    # the folded program returns BOTH gradients from one SPMD call
    dB, dbias = g._fused_bwd("relu")(out, sc, dOut)
    assert dB.shape == B.shape and dbias.shape == (dim,)
    ref_dbias = epilogue_grad(out, dOut, "relu").sum(axis=0)
    np.testing.assert_allclose(np.asarray(dbias), np.asarray(ref_dbias),
                               rtol=2e-4, atol=2e-5)
    # and the public grad path routes through it
    gbias = jax.grad(lambda bb: g.fused(B, scale=sc, bias=bb,
                                        activation="relu").sum())(b)
    np.testing.assert_allclose(np.asarray(gbias), np.asarray(ref_dbias),
                               rtol=2e-3, atol=2e-4)


@needs_mesh
def test_dist_train_gnn_multihead_gat():
    from repro.apps.gnn import train_gnn
    from repro.data.tasks import community_task

    task = community_task(n_blocks=4, block_size=32, seed=0)
    res = train_gnn(task, model="gat", hidden=16, n_layers=2, steps=6,
                    heads=2, partitions=2)
    assert isinstance(res.config, list) and len(res.config) == 2
    assert res.losses[-1] < res.losses[0]


# --------------------------------------- dynamic per-shard refresh (PR 9)
def _mutate_shard_rows(csr, part, rng, shard, n_new=10):
    """Add edges whose rows AND columns live inside one shard's row
    range — only that shard's local edge slice changes, and no halo can
    grow."""
    lo, hi = int(part.starts[shard]), int(part.starts[shard + 1])
    A = csr.to_dense()
    r = rng.integers(lo, hi, n_new)
    c = rng.integers(lo, hi, n_new)
    A[r, c] = rng.random(n_new).astype(np.float32) + 0.5
    return CSRMatrix.from_dense(A.astype(np.float32))


def test_refresh_reuses_unchanged_shards_identity(rng):
    """Host-side plan contract: a mutation confined to one shard leaves
    every other shard's Shard AND PCSR objects identity-preserved, the
    partition boundaries pinned, and the padded shapes unchanged."""
    csr = rmat(8, 6, seed=11)
    g = DistGraph(csr, 16, 4, strategy="balanced")
    old_shards = list(g.part.shards)
    old_pcsrs = list(g._fwd.pcsrs)
    old_starts = g.part.starts.copy()
    old_shape = (g.part.rows_pad, g.part.halo_pad)
    new_csr = _mutate_shard_rows(csr, g.part, rng, shard=1)
    rep = g.refresh(new_csr)
    assert rep.changed == [1]
    assert set(rep.reused) == {0, 2, 3}
    assert not rep.halo_pad_grew
    for p in rep.reused:
        assert g.part.shards[p] is old_shards[p]       # identity, not copy
        assert g._fwd.pcsrs[p] is old_pcsrs[p]
    assert g._fwd.pcsrs[1] is not old_pcsrs[1]
    np.testing.assert_array_equal(g.part.starts, old_starts)
    assert (g.part.rows_pad, g.part.halo_pad) == old_shape
    assert g.csr is new_csr
    # node set is fixed — a different row count is a re-partition, not
    # a refresh
    with pytest.raises(ValueError, match="fixed node set"):
        g.refresh(rmat(7, 6, seed=1))


def test_shard_drift_reports_changed_shards_only(rng):
    from repro.dynamic import shard_drift

    csr = rmat(8, 6, seed=3)
    g = DistGraph(csr, 16, 4, strategy="balanced")
    assert shard_drift(g, csr) == {}               # no change → no entries
    new_csr = _mutate_shard_rows(csr, g.part, rng, shard=2, n_new=6)
    out = shard_drift(g, new_csr)
    assert set(out) == {2}                         # only the mutated shard
    # a tight threshold turns the entry into a real advisory
    out_tight = shard_drift(g, new_csr, threshold=1e-6)
    assert out_tight[2] is not None and out_tight[2].drifted


@needs_mesh
def test_refresh_dist_spmm_matches_engine_after_mutation(rng):
    """End-to-end per-shard self-healing: after refresh the SPMD SpMM
    matches the single-device engine on the MUTATED graph, including a
    drift-triggered per-shard config re-pick observed via obs counters."""
    from repro import obs

    csr = rmat(8, 7, seed=9)
    dim = 16
    g = DistGraph(csr, dim, 4, strategy="balanced")
    _ = dist_spmm(g, jnp.zeros((csr.n_rows, dim), jnp.float32))  # warm
    new_csr = _mutate_shard_rows(csr, g.part, rng, shard=0, n_new=40)
    obs.reset_metrics()
    with obs.tracing():
        rep = g.refresh(new_csr, threshold=1e-6)   # force the re-pick path
        snap = obs.metrics_snapshot()
    obs.stop_tracing()
    assert rep.changed == [0] and rep.repicked == [0]
    assert 0 in rep.advisories
    assert sum(snap["dist_shard_repacks_total"].values()) == 1
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    cfg, _ = CostModel(new_csr).best(dim, config_space(dim))
    ref = engine_spmm(build_pcsr(new_csr.indptr, new_csr.indices,
                                 new_csr.data, new_csr.n_rows,
                                 new_csr.n_cols, cfg), B)
    _dist_tol(dist_spmm(g, B), ref)
    # grads flow through the refreshed transpose path too
    gd = jax.grad(lambda b: (dist_spmm(g, b) ** 2).sum())(B)
    t = new_csr.transpose()
    pt = build_pcsr(t.indptr, t.indices, t.data, t.n_rows, t.n_cols, cfg)
    ref_fn = make_spmm_fn(build_pcsr(new_csr.indptr, new_csr.indices,
                                     new_csr.data, new_csr.n_rows,
                                     new_csr.n_cols, cfg), pt)
    gr = jax.grad(lambda b: (ref_fn(b) ** 2).sum())(B)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gr),
                               rtol=2e-3, atol=2e-4)


@needs_mesh
def test_refresh_overlap_mode_rebuilds_changed_split_packs(rng):
    csr = rmat(8, 6, seed=21)
    dim = 12
    g = DistGraph(csr, dim, 4, strategy="balanced", overlap=True)
    _ = dist_spmm(g, jnp.zeros((csr.n_rows, dim), jnp.float32))
    old_loc = list(g._loc.pcsrs)
    new_csr = _mutate_shard_rows(csr, g.part, rng, shard=3, n_new=12)
    rep = g.refresh(new_csr)
    assert rep.changed == [3]
    for p in rep.reused:
        assert g._loc.pcsrs[p] is old_loc[p]
    assert g._loc.pcsrs[3] is not old_loc[3]
    B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
    cfg, _ = CostModel(new_csr).best(dim, config_space(dim))
    ref = engine_spmm(build_pcsr(new_csr.indptr, new_csr.indices,
                                 new_csr.data, new_csr.n_rows,
                                 new_csr.n_cols, cfg), B)
    _dist_tol(dist_spmm(g, B), ref)
