"""JAX engine vs oracle + custom-VJP gradient checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import ParamSpMMOperator, engine_spmm, make_spmm_fn
from repro.core.pcsr import SpMMConfig, build_pcsr, config_space
from repro.core.sparse import CSRMatrix

from conftest import random_csr


def test_engine_matches_dense_all_configs(rng):
    csr, A = random_csr(rng, 83, 0.07, skew=True)
    B = jnp.asarray(rng.standard_normal((83, 48)), jnp.float32)
    ref = A.astype(np.float32) @ np.asarray(B)
    for cfg in config_space(48):
        p = build_pcsr(csr.indptr, csr.indices, csr.data, 83, 83, cfg)
        out = np.asarray(engine_spmm(p, B))
        np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


def test_custom_vjp_matches_dense_grad(rng):
    csr, A = random_csr(rng, 41, 0.12)
    Bv = rng.standard_normal((41, 24)).astype(np.float32)
    op = ParamSpMMOperator(csr, SpMMConfig(V=2, S=True, W=8))

    def loss(b):
        y = op(b)
        return jnp.sum(jnp.sin(y))

    g = np.asarray(jax.grad(loss)(jnp.asarray(Bv)))
    Ad = A.astype(np.float32)
    g_ref = Ad.T @ np.cos(Ad @ Bv)
    np.testing.assert_allclose(g, g_ref, atol=1e-3, rtol=1e-3)


def test_pallas_backend_matches_engine(rng):
    csr, A = random_csr(rng, 37, 0.15)
    B = jnp.asarray(rng.standard_normal((37, 32)), jnp.float32)
    cfg = SpMMConfig(V=2, S=False, W=4)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 37, 37, cfg)
    f_pallas = make_spmm_fn(p, backend="pallas")
    np.testing.assert_allclose(np.asarray(f_pallas(B)),
                               np.asarray(engine_spmm(p, B)),
                               atol=1e-4, rtol=1e-4)


def test_rectangular_matrix(rng):
    A = ((rng.random((30, 50)) < 0.15)
         * rng.standard_normal((30, 50))).astype(np.float32)
    csr = CSRMatrix.from_dense(A)
    B = jnp.asarray(rng.standard_normal((50, 16)), jnp.float32)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 30, 50,
                   SpMMConfig(V=2, S=True, W=8))
    np.testing.assert_allclose(np.asarray(engine_spmm(p, B)), A @ np.asarray(B),
                               atol=1e-4, rtol=1e-4)
