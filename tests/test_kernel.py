"""Pallas kernel vs ref.py oracle: shape/dtype/config sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pcsr import SpMMConfig, build_pcsr
from repro.core.sparse import CSRMatrix
from repro.kernels.paramspmm import paramspmm, spmm_ref

from conftest import random_csr
from _propcheck import booleans, floats, integers, propcases, sampled_from


def _run(csr, dim, cfg, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    B = jnp.asarray(rng.standard_normal((csr.n_cols, dim)), dtype)
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, cfg)
    out = paramspmm(p, B, interpret=True)
    ref = spmm_ref(csr.indptr, csr.indices, csr.data,
                   B.astype(jnp.float32), csr.n_rows)
    return np.asarray(out, np.float32), np.asarray(ref)


CONFIGS = [SpMMConfig(V=1, S=False, F=1, W=8),
           SpMMConfig(V=2, S=False, F=1, W=8),
           SpMMConfig(V=1, S=True, F=1, W=16),
           SpMMConfig(V=2, S=True, F=2, W=4),
           SpMMConfig(V=1, S=True, F=2, W=32),
           SpMMConfig(V=2, S=False, F=4, W=16)]


@pytest.mark.parametrize("cfg", CONFIGS, ids=str)
@pytest.mark.parametrize("dim", [32, 96, 128, 200])
def test_kernel_allclose_f32(rng, cfg, dim):
    csr, _ = random_csr(rng, 67, 0.08)
    out, ref = _run(csr, dim, cfg)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("cfg", CONFIGS[:3], ids=str)
def test_kernel_allclose_bf16(rng, cfg):
    csr, _ = random_csr(rng, 40, 0.1)
    out, ref = _run(csr, 64, cfg, dtype=jnp.bfloat16)
    np.testing.assert_allclose(out, ref, atol=0.15, rtol=0.1)


def test_kernel_skewed(rng):
    csr, _ = random_csr(rng, 90, 0.03, skew=True)
    for cfg in (SpMMConfig(V=1, S=True, W=8), SpMMConfig(V=2, S=True, W=8)):
        out, ref = _run(csr, 64, cfg)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("case", propcases(
    12, n=integers(8, 50), dim=sampled_from([16, 64, 130]),
    density=floats(0.02, 0.3), v=sampled_from([1, 2]),
    s=booleans(), seed=integers(0, 99)), ids=str)
def test_kernel_property(case):
    rng = np.random.default_rng(case.seed)
    A = ((rng.random((case.n, case.n)) < case.density)
         * rng.standard_normal((case.n, case.n))).astype(np.float32)
    csr = CSRMatrix.from_dense(A)
    cfg = SpMMConfig(V=case.v, S=case.s, W=8 // case.v)
    out, ref = _run(csr, case.dim, cfg, seed=case.seed)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_kernel_rectangular_dim_padding(rng):
    """dim not a multiple of Dblk exercises the MAC-gap lane padding."""
    csr, _ = random_csr(rng, 33, 0.15)
    out, ref = _run(csr, 100, SpMMConfig(V=2, S=False, F=1, W=4))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_kernel_empty_blocks_zeroed(rng):
    """Regression: blocks with no nonzeros are never visited by the grid —
    their rows must come back exactly zero, not uninitialized."""
    A = ((rng.random((64, 64)) < 0.2)
         * rng.standard_normal((64, 64))).astype(np.float32)
    A[8:32] = 0.0                       # several fully-empty blocks
    csr = CSRMatrix.from_dense(A)
    for cfg in (SpMMConfig(V=2, S=True, W=4), SpMMConfig(V=1, S=False, W=8)):
        out, ref = _run(csr, 64, cfg)
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
