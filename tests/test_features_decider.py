"""Feature extraction (paper Table 3) + random forest + decider."""
import numpy as np
import pytest

from repro.core.decider import DecisionTree, RandomForest, SpMMDecider
from repro.core.features import FEATURE_NAMES, extract_features
from repro.core.pcsr import SpMMConfig
from repro.core.sparse import CSRMatrix


def test_features_on_crafted_matrix():
    # 4 rows: degrees 2,2,0,4 ; bandwidths 3,1,-,3
    A = np.array([[1, 0, 0, 1],
                  [0, 1, 1, 0],
                  [0, 0, 0, 0],
                  [1, 1, 1, 1]], np.float32)
    f = extract_features(CSRMatrix.from_dense(A)).as_dict()
    assert f["n"] == 4 and f["n_hat"] == 3 and f["nnz"] == 8
    assert f["d"] == 2.0 and f["d_max"] == 4.0
    assert abs(f["r"] - 0.75) < 1e-9
    assert f["bw_max"] == 3.0
    deg = np.array([2, 2, 0, 4.0])
    assert abs(f["cv"] - deg.std() / deg.mean()) < 1e-9
    assert f["pr_1"] == 0.0
    assert 0.0 <= f["pr_2"] <= 0.5


def test_forest_learns_separable():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((400, 6))
    y = (X[:, 2] > 0.3).astype(int) + 2 * (X[:, 4] > 0).astype(int)
    rf = RandomForest(n_estimators=20, seed=1).fit(X[:300], y[:300], 4)
    acc = (rf.predict(X[300:]) == y[300:]).mean()
    assert acc > 0.85


def test_tree_pure_leaf():
    X = np.ones((10, 3))
    y = np.zeros(10, np.int64)
    t = DecisionTree().fit(X, y, 2)
    assert (t.predict_proba(X).argmax(1) == 0).all()


def test_decider_masks_invalid_F():
    d = SpMMDecider()
    # fit on trivial data so forest exists
    from repro.core.features import MatrixFeatures
    f = MatrixFeatures(np.ones(len(FEATURE_NAMES)))
    big_f = [c for c in d.space if c.F == 4][0]
    d.fit([(f, 512, big_f)] * 8)
    pred = d.predict(f, 64)          # dim 64 → only F=1 valid
    assert pred.F == 1
