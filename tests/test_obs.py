"""Runtime telemetry layer (repro.obs): span nesting + Chrome-trace
schema, counter/label semantics, the structured decision log, drift
advisories, and the disabled-mode guarantees (zero events recorded,
traced and untraced training bit-identical)."""
import json

import numpy as np
import pytest

from repro import obs
from repro.obs import decisions as obs_decisions
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core.cost_model import CostModel
from repro.core.pcsr import SpMMConfig, build_pcsr, config_space
from repro.core.sparse import CSRMatrix

from conftest import random_csr


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends untraced with empty registries — a
    failing test must not leak an active session into the next one."""
    if obs.trace_enabled():                            # pragma: no cover
        obs.stop_tracing()
    obs.reset_metrics()
    obs.clear_decisions()
    yield
    if obs.trace_enabled():
        obs.stop_tracing()
    obs.reset_metrics()
    obs.clear_decisions()


# ------------------------------------------------------- disabled mode
def test_disabled_mode_records_nothing():
    assert not obs.trace_enabled()
    with obs.span("work", step=1):
        obs.instant("tick")
    obs.counter("c_test").inc()
    obs.gauge("g_test").set(3.0)
    obs.histogram("h_test").observe(1.0)
    assert obs.trace_events() == []
    assert obs.metrics_snapshot() == {}
    assert obs_decisions.record_decision(
        source="cost_model", dim=32, chosen=(8, 1, 1, False, False)) is None
    assert obs.decision_log() == []


def test_disabled_span_is_the_shared_null_singleton():
    # the near-zero-overhead contract: no allocation per disabled span
    assert obs.span("a") is obs.span("b", x=1)


# ------------------------------------------- spans + chrome-trace export
def test_span_nesting_and_chrome_trace_schema(tmp_path):
    path = tmp_path / "t.json"
    with obs.tracing(str(path)):
        with obs.span("outer", kind="demo"):
            with obs.span("inner"):
                obs.instant("mark", note="hi")
        obs.counter("c_events").inc(2.0, phase="x")
    payload = json.loads(path.read_text())
    evs = payload["traceEvents"]

    complete = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(complete) >= {"outer", "inner"}
    for e in complete.values():        # chrome "X" schema
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["dur"] >= 0
    # nesting by containment: inner's interval lies inside outer's
    o, i = complete["outer"], complete["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-6
    assert o["args"]["kind"] == "demo"

    inst = [e for e in evs if e["ph"] == "i" and e["name"] == "mark"]
    assert inst and inst[0]["s"] == "t" and inst[0]["args"]["note"] == "hi"
    # one final "C" counter event per series so Perfetto renders totals
    cnt = [e for e in evs if e["ph"] == "C" and "c_events" in e["name"]]
    assert cnt and cnt[0]["args"]["value"] == 2.0
    assert payload["repro_metrics"]["c_events"] == {"phase=x": 2.0}


def test_nested_start_tracing_raises():
    obs.start_tracing()
    with pytest.raises(RuntimeError, match="already active"):
        obs.start_tracing()
    obs.stop_tracing()


def test_tracing_session_is_its_own_window(tmp_path):
    with obs.tracing():
        obs.counter("c_window").inc(5.0)
        obs_decisions.record_decision(
            source="cost_model", dim=16, chosen=(8, 1, 1, False, False))
    assert len(obs.decision_log()) == 1     # decisions survive the stop
    with obs.tracing():                     # ... until the next session
        assert obs.metrics_snapshot() == {}
        assert obs.decision_log() == []


# ------------------------------------------------------------- metrics
def test_counter_label_semantics():
    with obs.tracing():
        c = obs.counter("c_lbl")
        c.inc(a="1", b="2")
        c.inc(2.0, b="2", a="1")            # kw order must not matter
        c.inc(a="1", b="3")                 # distinct series
        c.inc()                             # unlabeled series
        snap = obs.metrics_snapshot()["c_lbl"]
    assert snap == {"a=1,b=2": 3.0, "a=1,b=3": 1.0, "": 1.0}


def test_gauge_and_histogram_semantics():
    with obs.tracing():
        obs.gauge("g_sem").set(1.0, shard=0)
        obs.gauge("g_sem").set(4.0, shard=0)        # last write wins
        h = obs.histogram("h_sem")
        for v in (1.0, 3.0, 2.0):
            h.observe(v)
        snap = obs.metrics_snapshot()
    assert snap["g_sem"] == {"shard=0": 4.0}
    assert snap["h_sem"][""] == {"count": 3, "sum": 6.0,
                                 "min": 1.0, "max": 3.0}


def test_metric_kind_mismatch_raises():
    obs.counter("m_kind")
    with pytest.raises(TypeError, match="counter"):
        obs.gauge("m_kind")


def test_pallas_probe_counts_launches(rng):
    """A kernel traced during the session shows up in
    pallas_calls_total — same interception ``count_pallas_calls`` uses."""
    from repro.kernels.paramspmm.ops import paramspmm

    csr, _ = random_csr(rng, 43, 0.2)      # fresh shape → no jit cache hit
    p = build_pcsr(csr.indptr, csr.indices, csr.data, 43, 43,
                   SpMMConfig(V=1, S=False, W=8, F=1))
    B = np.asarray(rng.standard_normal((43, 8)), np.float32)
    with obs.tracing():
        paramspmm(p, B, interpret=True)
        snap = obs.metrics_snapshot()
    series = snap.get("pallas_calls_total", {})
    assert series and sum(series.values()) >= 1, snap.keys()


# -------------------------------------------------------- decision log
def test_cost_model_best_records_decision(rng, tmp_path):
    csr, _ = random_csr(rng, 64, 0.1)
    path = tmp_path / "d.json"
    with obs.tracing(str(path)):
        cfg, _ = CostModel(csr).best(32, config_space(32))
    log = obs.decision_log()
    assert len(log) == 1
    rec = log[0]
    assert rec.source == "cost_model" and rec.op == "spmm"
    assert rec.dim == 32 and rec.chosen == tuple(cfg.astuple())
    assert rec.calibration is None          # analytic constants
    # top-k candidates sorted cheapest-first, chosen == cheapest
    secs = [c["seconds"] for c in rec.topk]
    assert secs == sorted(secs) and len(secs) >= 2
    assert tuple(rec.topk[0]["config"]) == rec.chosen
    assert rec.predicted_seconds == pytest.approx(secs[0])
    for name in obs_decisions.DRIFT_FEATURES:
        assert name in rec.snapshot
    # round-trip through the exported trace
    payload = json.loads(path.read_text())
    [d] = payload["repro_decisions"]
    assert d["chosen"] == list(rec.chosen)
    assert d["snapshot"]["nnz"] == rec.snapshot["nnz"]
    assert payload["repro_metrics"]["decisions_total"] == {
        "op=spmm,source=cost_model": 1.0}


def test_record_decision_scores_rank_highest_first():
    space = [(8, 1, 1, False, False), (8, 2, 1, False, False),
             (16, 1, 2, True, False)]
    with obs.tracing():
        rec = obs_decisions.record_decision(
            source="decider", dim=64, chosen=space[1],
            scores=zip(space, [0.2, 0.7, 0.1]), snapshot={"n": 1.0}, k=2)
    assert [c["score"] for c in rec.topk] == [0.7, 0.2]
    assert tuple(rec.topk[0]["config"]) == space[1]


# ----------------------------------------------------- drift advisories
def _densified(csr, rng):
    A = csr.to_dense()
    extra = (rng.random(A.shape) < 0.3).astype(np.float32)
    return CSRMatrix.from_dense(A + extra)


def test_drift_advisory_fires_on_mutated_graph_only(rng):
    csr, _ = random_csr(rng, 64, 0.05)
    with obs.tracing():
        CostModel(csr).best(32, config_space(32))
    # post-trace: same graph → quiet
    assert obs_decisions.check_drift(csr) is None
    # densified graph → advisory naming the moved features + the pick
    adv = obs_decisions.check_drift(_densified(csr, rng))
    assert adv is not None and "nnz" in adv.drifted
    assert adv.drifted["nnz"]["rel"] > obs_decisions.DRIFT_THRESHOLD
    assert str(adv.record.chosen) in adv.message
    assert "re-run config selection" in adv.message


def test_check_drift_without_decisions_raises(rng):
    csr, _ = random_csr(rng, 32, 0.1)
    with pytest.raises(ValueError, match="no decision"):
        obs_decisions.check_drift(csr)


# ------------------------------------- traced == untraced (gnn training)
def test_traced_training_matches_untraced(tmp_path):
    from repro.apps.gnn import train_gnn
    from repro.data.tasks import community_task

    task = community_task(n_blocks=4, block_size=32, feat_dim=8,
                          p_in=0.3, seed=0)
    kw = dict(model="gcn", hidden=16, n_layers=2, steps=4, seed=0)
    base = train_gnn(task, **kw)
    path = tmp_path / "gnn.json"
    with obs.tracing(str(path)):
        traced = train_gnn(task, **kw)
    # observability must not perturb the computation
    np.testing.assert_array_equal(np.asarray(base.losses),
                                  np.asarray(traced.losses))
    payload = json.loads(path.read_text())
    names = {e["name"] for e in payload["traceEvents"] if e["ph"] == "X"}
    assert {"gnn.pack", "gnn.compile", "gnn.step"} <= names
    hits = payload["repro_metrics"].get("pack_cache_hits_total", {})
    assert sum(hits.values()) >= 1          # steering cache observed
    assert payload["repro_decisions"]        # config pick logged


# ----------------------------------------------------------- obs_report
def test_obs_report_summarizes_trace(tmp_path, capsys, rng):
    from repro.apps import obs_report

    csr, _ = random_csr(rng, 48, 0.1)
    path = tmp_path / "r.json"
    with obs.tracing(str(path)):
        with obs.span("outer"):
            with obs.span("inner"):
                CostModel(csr).best(16, config_space(16))
    assert obs_report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "outer" in out and "inner" in out
    assert "cost_model" in out               # decision summary
    assert "decisions_total" in out          # counter section


def test_obs_report_rejects_non_trace_file(tmp_path, capsys):
    from repro.apps import obs_report

    bad = tmp_path / "bad.json"
    bad.write_text("{\"rows\": []}")
    assert obs_report.main([str(bad)]) == 1
    missing = tmp_path / "nope.json"
    assert obs_report.main([str(missing)]) == 1


# -------------------------------------------------------- env autostart
def test_env_autostart(tmp_path, monkeypatch):
    path = tmp_path / "env.json"
    monkeypatch.setenv("REPRO_TRACE", str(path))
    obs_trace._env_autostart()
    assert obs.trace_enabled()
    obs.instant("from_env")
    assert obs.stop_tracing() == str(path)   # atexit re-run is a no-op
    payload = json.loads(path.read_text())
    assert any(e["name"] == "from_env" for e in payload["traceEvents"])


# ------------------------------------------- configurable drift thresholds
def test_resolve_drift_thresholds_scalar_dict_env(monkeypatch):
    r = obs_decisions.resolve_drift_thresholds
    monkeypatch.delenv(obs_decisions.DRIFT_THRESHOLD_ENV, raising=False)
    # default: every feature at DRIFT_THRESHOLD
    assert r() == {f: obs_decisions.DRIFT_THRESHOLD
                   for f in obs_decisions.DRIFT_FEATURES}
    # scalar broadcast
    assert r(0.5) == {f: 0.5 for f in obs_decisions.DRIFT_FEATURES}
    # partial dict overrides ride on the default base
    t = r({"nnz": 0.05, "cv": 2.0})
    assert t["nnz"] == 0.05 and t["cv"] == 2.0
    assert t["d_max"] == obs_decisions.DRIFT_THRESHOLD
    with pytest.raises(ValueError, match="unknown drift feature"):
        r({"not_a_feature": 0.1})
    # env hook: scalar form, then per-feature list form
    monkeypatch.setenv(obs_decisions.DRIFT_THRESHOLD_ENV, "0.4")
    assert r()["nnz"] == 0.4
    monkeypatch.setenv(obs_decisions.DRIFT_THRESHOLD_ENV,
                       "nnz=0.02, cv=1.5")
    t = r()
    assert t["nnz"] == 0.02 and t["cv"] == 1.5
    assert t["rho"] == obs_decisions.DRIFT_THRESHOLD
    # an explicit argument beats the env
    assert r(0.9)["nnz"] == 0.9


def test_check_drift_per_feature_threshold_and_advisory_record(rng):
    csr, _ = random_csr(rng, 64, 0.05)
    with obs.tracing():
        CostModel(csr).best(32, config_space(32))
    mutated = _densified(csr, rng)
    # a sky-high nnz threshold silences the nnz advisory dimension
    loose = obs_decisions.check_drift(mutated, threshold={"nnz": 100.0})
    assert loose is None or "nnz" not in loose.drifted
    # a tight one fires, and the advisory records WHICH threshold fired
    adv = obs_decisions.check_drift(mutated, threshold={"nnz": 0.01})
    assert adv is not None and "nnz" in adv.drifted
    assert adv.drifted["nnz"]["threshold"] == 0.01
    assert "1%" in adv.message                 # the fired bound, printed
