"""Optimizer, gradient compression, and MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import moe_ffn, _positions_in_expert
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         topk_compress_apply, topk_compress_init)


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal(12).astype(np.float32))}
    cfg = AdamWConfig(lr=0.1)
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0)
    state = adamw_init(params)
    p2, _ = adamw_update(params, g, state, cfg)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_topk_error_feedback_conserves_signal():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(100).astype(np.float32))}
    err = topk_compress_init(g)
    sent, new_err = topk_compress_apply(g, err, frac=0.1)
    # sent + residual == grad (+ previous error, zero here)
    np.testing.assert_allclose(np.asarray(sent["w"] + new_err["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    # sparsity
    nz = (np.asarray(sent["w"]) != 0).sum()
    assert nz <= 11
    # second round drains accumulated error
    sent2, err2 = topk_compress_apply(
        {"w": jnp.zeros(100)}, new_err, frac=0.1)
    assert float(jnp.abs(err2["w"]).sum()) < float(jnp.abs(new_err["w"]).sum())


def test_positions_in_expert():
    eidx = jnp.asarray([0, 1, 0, 0, 1, 2])
    pos = np.asarray(_positions_in_expert(eidx, 3))
    np.testing.assert_array_equal(pos, [0, 0, 1, 2, 1, 0])


def test_moe_matches_dense_mixture_when_capacity_ample():
    """top_k=E with generous capacity ⇒ exactly the softmax-weighted
    mixture of all experts (dense reference)."""
    rng = np.random.default_rng(0)
    B, S, D, E, eff = 2, 8, 16, 4, 32
    x = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    router = jnp.asarray(rng.standard_normal((D, E)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((E, D, eff)).astype(np.float32)) * 0.1
    wu = jnp.asarray(rng.standard_normal((E, D, eff)).astype(np.float32)) * 0.1
    wd = jnp.asarray(rng.standard_normal((E, eff, D)).astype(np.float32)) * 0.1
    out = moe_ffn(x, router, wg, wu, wd, top_k=E, act="silu",
                  capacity_factor=4.0)
    gates = jax.nn.softmax((x.reshape(-1, D) @ router), axis=-1)
    ref = jnp.zeros((B * S, D))
    for e in range(E):
        h = jax.nn.silu(x.reshape(-1, D) @ wg[e]) * (x.reshape(-1, D) @ wu[e])
        ref = ref + gates[:, e:e + 1] * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, D),
                               np.asarray(ref), atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_overflow():
    """All tokens to one expert with tiny capacity: output is bounded and
    finite (static-shape overflow handling, no recompiles)."""
    B, S, D, E, eff = 1, 16, 8, 4, 8
    x = jnp.ones((B, S, D))
    router = jnp.zeros((D, E)).at[:, 0].set(10.0)   # all → expert 0
    wg = jnp.ones((E, D, eff)) * 0.1
    wu = jnp.ones((E, D, eff)) * 0.1
    wd = jnp.ones((E, eff, D)) * 0.1
    out = moe_ffn(x, router, wg, wu, wd, top_k=1, act="silu",
                  capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(out)))
    # exactly cap tokens got routed; the rest dropped to zero
    nonzero_rows = (jnp.abs(out.reshape(-1, D)).sum(-1) > 1e-6).sum()
    assert int(nonzero_rows) <= 8
