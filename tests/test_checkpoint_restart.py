"""Fault tolerance: checkpoint atomicity, async save, restart continuity,
stateless data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data.tokens import batch_for_step


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"a": jnp.arange(5.0), "b": [jnp.ones((2, 3)), jnp.int32(7)]}
    mgr.save(3, tree)
    step, back = mgr.restore()
    assert step == 3
    assert np.allclose(back["a"], np.arange(5.0))
    assert int(back["b"][1]) == 7


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 5, 9):
        mgr.save(s, {"x": jnp.float32(s)})
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]          # step 1 collected
    step, tree = mgr.restore()
    assert float(tree["x"]) == 9.0


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(2, {"x": jnp.arange(10)})
    mgr.wait()
    step, tree = mgr.restore()
    assert step == 2 and np.allclose(tree["x"], np.arange(10))


def test_data_pipeline_stateless():
    cfg = get_reduced("qwen2-72b")
    b1 = batch_for_step(cfg, 4, 16, step=7, seed=1)
    b2 = batch_for_step(cfg, 4, 16, step=7, seed=1)
    b3 = batch_for_step(cfg, 4, 16, step=8, seed=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])


@pytest.mark.slow
def test_train_restart_continuity(tmp_path):
    """Kill-and-resume: continued run behaves as if never interrupted.
    (Losses beyond the restart can't be bitwise-compared — optimizer
    state round-trips through f32 exactly, but donation/layout may
    reorder reductions — so we check step continuity + loss sanity.)"""
    from repro.launch.train import train
    ck = str(tmp_path / "ck")
    l_full = train(["--arch", "granite-moe-1b-a400m", "--reduced",
                    "--steps", "14", "--batch", "2", "--seq", "16",
                    "--ckpt-dir", str(tmp_path / "full"),
                    "--ckpt-every", "50"])
    train(["--arch", "granite-moe-1b-a400m", "--reduced",
           "--steps", "7", "--batch", "2", "--seq", "16",
           "--ckpt-dir", ck, "--ckpt-every", "3"])
    l_resumed = train(["--arch", "granite-moe-1b-a400m", "--reduced",
                       "--steps", "14", "--batch", "2", "--seq", "16",
                       "--ckpt-dir", ck, "--ckpt-every", "50", "--resume"])
    # resumed run continues from step 7 and ends near the full run's loss
    assert len(l_resumed) <= 8
    assert abs(l_resumed[-1] - l_full[-1]) < 0.35


def test_crash_mid_save_keeps_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"x": jnp.float32(1)})
    # simulate a crash that left a stale tmp dir
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_2"), exist_ok=True)
    assert mgr.latest_step() == 1
    step, tree = mgr.restore()
    assert step == 1 and float(tree["x"]) == 1.0
