"""Dynamic-graph robustness layer (repro.dynamic): incremental PCSR
maintenance must stay BIT-exact under any insert/delete/re-pack stream
(slack slots, delta chunks, tombstones, empty-block birth/death, fat-row
growth), the governor must auto-trigger re-packs once priced degradation
crosses the slack threshold (observed through obs counters + decision
log), and ``reselect`` may only ever change the layout-free F axis.

Bit-exactness strategy: integer-valued float32 edge weights and
features — float32 adds of small integers are exact in any order, so a
degraded layout and a fresh pack must produce *identical* bits, not
merely close ones."""
import numpy as np
import pytest

import jax.numpy as jnp

import _propcheck as pc

from repro import obs
from repro.core import CostModel, CSRMatrix, SpMMConfig, build_pcsr, \
    config_space
from repro.core.cost_model import degraded_kernel_cost, kernel_cost, \
    pack_setup_seconds, pcsr_stats
from repro.core.engine import engine_spmm, make_gat_message_fn, make_spmm_fn
from repro.dynamic import DynamicGraph, DynamicPCSR, RepackGovernor


@pytest.fixture(autouse=True)
def _clean_obs():
    if obs.trace_enabled():                            # pragma: no cover
        obs.stop_tracing()
    obs.reset_metrics()
    obs.clear_decisions()
    yield
    if obs.trace_enabled():
        obs.stop_tracing()
    obs.reset_metrics()
    obs.clear_decisions()


def _int_csr(rng, n, density=0.12):
    """Integer-valued adjacency → order-independent float32 sums."""
    A = ((rng.random((n, n)) < density)
         * rng.integers(1, 8, (n, n))).astype(np.float32)
    return CSRMatrix.from_dense(A), A


def _int_feats(rng, n, d):
    return jnp.asarray(rng.integers(-3, 4, (n, d)), jnp.float32)


def _fresh_spmm(csr, config, B):
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, config)
    return np.asarray(engine_spmm(p, B))


def _edges_of(csr):
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), csr.degrees)
    return rows, csr.indices


def _mutate(rng, dyn, n, step):
    """One randomized mutation batch: insert / delete / full re-pack."""
    op = int(rng.integers(0, 4))
    if op == 3 and step > 0:
        dyn.repack()
        return
    if op == 2 and dyn.nnz:
        rows, cols = _edges_of(dyn.to_csr())
        m = min(int(rng.integers(1, 16)), rows.size)
        pick = rng.choice(rows.size, size=m, replace=False)
        dyn.delete_edges(rows[pick], cols[pick])
        return
    m = int(rng.integers(1, 24))
    dyn.insert_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                     rng.integers(1, 8, m).astype(np.float32))


# ----------------------------------------------- bit-exact mutation stream
@pytest.mark.parametrize("case", pc.propcases(
    5, n=pc.integers(16, 48), density=pc.floats(0.04, 0.2),
    v=pc.sampled_from([1, 2]), s=pc.booleans(), b=pc.booleans(),
    seed=pc.integers(0, 99)), ids=str)
def test_mutation_stream_spmm_bit_exact_vs_fresh_pack(case):
    """The tentpole acceptance bar: after ANY randomized sequence of
    insert/delete/re-pack batches, the degraded view's SpMM is
    bit-identical to a from-scratch ``build_pcsr`` of the mutated CSR —
    on the engine backend at every step, on Pallas at the end."""
    rng = np.random.default_rng(case.seed)
    csr, _ = _int_csr(rng, case.n, case.density)
    cfg = SpMMConfig(V=case.v, S=case.s, W=8 // case.v,
                     B=case.b and case.s)        # B=True requires S=True
    dyn = DynamicPCSR.from_csr(csr, cfg)
    B = _int_feats(rng, case.n, 9)
    for step in range(7):
        _mutate(rng, dyn, case.n, step)
        view = dyn.pcsr
        # grouped-trow invariant: each block's chunks are contiguous
        trow = view.trow
        changes = int((np.diff(trow) != 0).sum())
        assert changes == len(set(trow.tolist())) - 1
        np.testing.assert_array_equal(
            np.asarray(engine_spmm(view, B)),
            _fresh_spmm(dyn.to_csr(), cfg, B))
    # the Pallas kernel consumes the same degraded view unchanged
    from repro.kernels.paramspmm.ops import paramspmm
    np.testing.assert_array_equal(
        np.asarray(paramspmm(dyn.pcsr, B, interpret=True)),
        _fresh_spmm(dyn.to_csr(), cfg, B))


def test_empty_block_birth_and_death(rng):
    """Inserting into a never-targeted block appends a delta chunk for it
    (birth); deleting a block's last edge tombstones it without removing
    the chunk — both stay exact and the CSR round-trips."""
    n = 64
    A = np.zeros((n, n), np.float32)
    A[:16] = (rng.random((16, n)) < 0.3) * rng.integers(1, 5, (16, n))
    csr = CSRMatrix.from_dense(A.astype(np.float32))
    cfg = SpMMConfig(V=2, S=True, W=4)
    dyn = DynamicPCSR.from_csr(csr, cfg)
    blocks0 = dyn.n_visited_blocks
    B = _int_feats(rng, n, 8)
    # birth: rows 40..47 live in blocks nothing targeted at pack time
    dyn.insert_edges([40, 41, 47], [3, 9, 60], [2.0, 3.0, 1.0])
    assert dyn.n_visited_blocks > blocks0
    assert dyn.n_delta_chunks >= 1
    np.testing.assert_array_equal(np.asarray(engine_spmm(dyn.pcsr, B)),
                                  _fresh_spmm(dyn.to_csr(), cfg, B))
    # death: delete every edge of row band 0..7 (its block empties)
    rows, cols = _edges_of(dyn.to_csr())
    sel = rows < 8
    dyn.delete_edges(rows[sel], cols[sel])
    out = np.asarray(engine_spmm(dyn.pcsr, B))
    np.testing.assert_array_equal(out,
                                  _fresh_spmm(dyn.to_csr(), cfg, B))
    assert (out[:8] == 0).all()
    # round-trip: the mutated edge set is what to_csr says it is
    back = dyn.to_csr()
    assert back.nnz == dyn.nnz
    np.testing.assert_array_equal(dyn.repack().n_rows, n)
    np.testing.assert_array_equal(np.asarray(engine_spmm(dyn.pcsr, B)),
                                  _fresh_spmm(back, cfg, B))


def test_fat_row_growth_spills_into_delta_chunks(rng):
    """A row outgrowing its packed capacity keeps spilling into appended
    delta chunks — exact throughout, and the governor's live extents see
    the growth."""
    n = 48
    csr, _ = _int_csr(rng, n, 0.05)
    cfg = SpMMConfig(V=1, S=True, W=8)
    dyn = DynamicPCSR.from_csr(csr, cfg)
    chunks0, B = dyn.num_chunks, _int_feats(rng, n, 6)
    cols = rng.permutation(n)[:40]
    dyn.insert_edges(np.full(40, 3), cols,
                     rng.integers(1, 6, 40).astype(np.float32))
    assert dyn.n_delta_chunks > 0 and dyn.num_chunks > chunks0
    np.testing.assert_array_equal(np.asarray(engine_spmm(dyn.pcsr, B)),
                                  _fresh_spmm(dyn.to_csr(), cfg, B))


def test_gat_exact_on_degraded_layout(rng):
    """The fused GAT message over a degraded view matches the same
    message over a fresh pack of the mutated CSR (tight tolerance —
    softmax is not bit-stable across summation orders)."""
    n = 40
    csr, _ = _int_csr(rng, n, 0.1)
    cfg = SpMMConfig(V=2, S=True, W=4)
    dyn = DynamicPCSR.from_csr(csr, cfg)
    for step in range(4):
        _mutate(rng, dyn, n, 0)        # step=0 → no repack: stay degraded
    assert dyn.n_slack_inserts + dyn.n_delta_chunks + dyn.n_tombstones > 0
    cur = dyn.to_csr()
    fresh = build_pcsr(cur.indptr, cur.indices, cur.data, n, n, cfg)
    Q = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((n, 5)), jnp.float32)
    for backend in ("engine", "pallas"):
        out = make_gat_message_fn(dyn.pcsr, backend=backend)(Q, K, Vf)
        ref = make_gat_message_fn(fresh, backend=backend)(Q, K, Vf)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------------ API contracts
def test_insert_rejects_zero_values_and_out_of_range(rng):
    csr, _ = _int_csr(rng, 16)
    dyn = DynamicPCSR.from_csr(csr, SpMMConfig(V=1, S=False, W=8))
    with pytest.raises(ValueError, match="value exactly 0"):
        dyn.insert_edges([1], [2], [0.0])
    with pytest.raises(ValueError, match="fixed node set"):
        dyn.insert_edges([16], [2], [1.0])
    with pytest.raises(ValueError, match="match in length"):
        dyn.insert_edges([1, 2], [3], [1.0])


def test_delete_missing_is_counted_not_raised(rng):
    csr, _ = _int_csr(rng, 16)
    dyn = DynamicPCSR.from_csr(csr, SpMMConfig(V=1, S=False, W=8))
    rep = dyn.delete_edges([0, 1], [0, 1])
    assert rep.missing + rep.deleted == 2
    v0 = dyn.version
    rep2 = dyn.delete_edges([0], [0])          # replayed delete: now gone
    assert rep2.missing == 1 and rep2.deleted == 0
    # an all-missing batch must not bump the version (no re-traces)
    assert dyn.version == v0


def test_mutation_report_counts_slack_vs_delta(rng):
    csr, _ = _int_csr(rng, 32, 0.08)
    dyn = DynamicPCSR.from_csr(csr, SpMMConfig(V=2, S=True, W=4))
    m = 30
    rng2 = np.random.default_rng(7)
    rep = dyn.insert_edges(rng2.integers(0, 32, m),
                           rng2.integers(0, 32, m),
                           rng2.integers(1, 5, m).astype(np.float32))
    assert rep.inserted + rep.updated == m
    assert rep.slack_inserts == dyn.n_slack_inserts   # per-batch == total
    assert rep.delta_chunks == dyn.n_delta_chunks
    # update-in-place does not claim a slot
    rows, cols = _edges_of(dyn.to_csr())
    rep2 = dyn.insert_edges(rows[:5], cols[:5],
                            np.full(5, 7.0, np.float32))
    assert rep2.updated == 5 and rep2.slack_inserts == 0


def test_reselect_only_changes_f(rng):
    csr, _ = _int_csr(rng, 32, 0.1)
    cfg = SpMMConfig(V=2, S=True, W=4, F=1)
    dyn = DynamicPCSR.from_csr(csr, cfg)
    with pytest.raises(ValueError, match="only change F"):
        dyn.reselect(SpMMConfig(V=1, S=True, W=8, F=1))
    with pytest.raises(ValueError, match="only change F"):
        dyn.reselect(SpMMConfig(V=2, S=False, W=4, F=1))
    v0 = dyn.version
    dyn.reselect(SpMMConfig(V=2, S=True, W=4, F=2))
    assert dyn.config.F == 2 and dyn.version == v0 + 1
    assert dyn.pcsr.config.F == 2
    B = _int_feats(rng, 32, 9)
    np.testing.assert_array_equal(
        np.asarray(engine_spmm(dyn.pcsr, B)),
        _fresh_spmm(dyn.to_csr(), cfg, B))


def test_repack_clears_layout_debt(rng):
    csr, _ = _int_csr(rng, 40, 0.1)
    dyn = DynamicPCSR.from_csr(csr, SpMMConfig(V=2, S=True, W=4))
    for _ in range(3):
        _mutate(rng, dyn, 40, 0)
    v0 = dyn.version
    dyn.repack()
    assert dyn.version == v0 + 1
    assert dyn.n_delta_chunks == 0 and dyn.n_tombstones == 0
    # a fresh pack of the same edge set has the same slot count
    fresh = DynamicPCSR.from_csr(dyn.to_csr(), dyn.config)
    assert dyn.num_chunks == fresh.num_chunks


# -------------------------------------------------- governor + pricing
def test_degraded_cost_matches_kernel_cost_on_fresh_layout(rng):
    """On an unmutated layout the degraded pricing must agree with
    ``kernel_cost`` of the same stats — same roofline, same features."""
    csr, _ = _int_csr(rng, 64, 0.1)
    cfg = SpMMConfig(V=2, S=True, W=4)
    dyn = DynamicPCSR.from_csr(csr, cfg)
    st = pcsr_stats(csr.indptr, csr.indices, 64, 64, cfg.V, cfg.W)
    a = kernel_cost(st, 32, cfg)
    b = degraded_kernel_cost(32, cfg, C=dyn.num_chunks, K=dyn.K,
                             n_blocks_visited=dyn.n_visited_blocks)
    assert b.steps == a.steps and b.total == pytest.approx(a.total)
    # and degradation strictly raises the priced time
    dyn.insert_edges(np.full(30, 1), np.arange(30),
                     np.ones(30, np.float32))
    worse = degraded_kernel_cost(32, cfg, C=dyn.num_chunks, K=dyn.K,
                                 n_blocks_visited=dyn.n_visited_blocks)
    assert worse.total >= b.total
    assert pack_setup_seconds(csr.nnz) > pack_setup_seconds(0) > 0


def test_governor_auto_repack_under_churn_with_counters(rng):
    """End-to-end bounded staleness: a churn stream degrades the layout
    until the priced gap exceeds slack, the governor fires a re-pack
    (visible in obs counters + the decision log), and every SpMM along
    the way is bit-exact."""
    n = 96
    csr, _ = _int_csr(rng, n, 0.06)
    B = _int_feats(rng, n, 16)
    with obs.tracing():
        g = DynamicGraph(csr, 16, slack=1.05, amortize_steps=10)
        for step in range(6):
            m = 150
            g.insert_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                           rng.integers(1, 5, m).astype(np.float32))
            rows, cols = _edges_of(g.dyn.to_csr())
            pick = rng.choice(rows.size, size=min(140, rows.size),
                              replace=False)
            g.delete_edges(rows[pick], cols[pick])
            np.testing.assert_array_equal(
                np.asarray(g.spmm(B)),
                _fresh_spmm(g.dyn.to_csr(), g.config, B))
        actions = [d.action for d in g.decisions]
        assert "repack" in actions, actions
        snap = obs.metrics_snapshot()
        assert sum(snap["dynamic_repacks_total"].values()) >= 1
        assert sum(snap["governor_decisions_total"].values()) \
            == len(actions)
        assert "dynamic_mutations_total" in snap
        log = [d for d in obs.decision_log() if d.source == "governor"]
        assert any(d.snapshot["action"] == "repack" for d in log)
    # post-repack the governor is rebaselined: an untouched graph idles
    dec = g.governor.evaluate(g.dyn, g.config)
    assert dec.action == "none"


def test_governor_advisory_only_when_auto_heal_off(rng):
    n = 64
    csr, _ = _int_csr(rng, n, 0.06)
    g = DynamicGraph(csr, 16, slack=1.0, amortize_steps=1000,
                     auto_heal=False)
    for _ in range(3):
        m = 120
        g.insert_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                       rng.integers(1, 5, m).astype(np.float32))
        rows, cols = _edges_of(g.dyn.to_csr())
        pick = rng.choice(rows.size, size=110, replace=False)
        g.delete_edges(rows[pick], cols[pick])
    assert any(d.action == "repack" for d in g.decisions)
    # advisory-only: the layout debt was NOT cleared
    assert g.dyn.n_tombstones + g.dyn.n_delta_chunks \
        + g.dyn.n_slack_inserts > 0
    # manual heal returns the layout to a fresh pack
    B = _int_feats(rng, n, 16)
    g.repack()
    assert g.dyn.n_tombstones == 0 and g.dyn.n_delta_chunks == 0
    np.testing.assert_array_equal(
        np.asarray(g.spmm(B)),
        _fresh_spmm(g.dyn.to_csr(), g.config, B))


def test_governor_fast_path_and_threshold_plumbing(rng):
    """No drift + within slack → 'none' without a config sweep; the
    per-feature drift threshold reaches ``check_drift`` through the
    governor."""
    csr, _ = _int_csr(rng, 48, 0.1)
    cfg, _ = CostModel(csr).best(16, config_space(16))
    dyn = DynamicPCSR.from_csr(csr, cfg)
    gov = RepackGovernor(16, slack=1.25, amortize_steps=100,
                         drift_threshold={"nnz": 10.0})
    gov.rebaseline(dyn, cfg)
    dec = gov.evaluate(dyn, cfg)
    assert dec.action == "none" and dec.advisory is None
    # one tiny insert: still within slack, still quiet
    dyn.insert_edges([0], [1], [1.0])
    assert gov.evaluate(dyn, cfg).action == "none"


def test_dynamic_graph_versioned_closure_rebuild(rng):
    """Jitted closures capture steering arrays at build time — the graph
    must rebuild them when (and only when) the version moves."""
    n = 32
    csr, _ = _int_csr(rng, n, 0.1)
    g = DynamicGraph(csr, 8, auto_heal=False)
    B = _int_feats(rng, n, 8)
    out0 = np.asarray(g.spmm(B))
    fn0 = g._spmm_fn
    _ = g.spmm(B)
    assert g._spmm_fn is fn0                  # no version move → cached
    g.insert_edges([0], [n - 1], [3.0])
    out1 = np.asarray(g.spmm(B))
    assert g._spmm_fn is not fn0              # rebuilt after mutation
    np.testing.assert_array_equal(out1,
                                  _fresh_spmm(g.dyn.to_csr(), g.config, B))
    assert not np.array_equal(out0, out1)
