#!/usr/bin/env bash
# Tier-1 verify: fast test tier + bytecode-compile + import/docs checks.
#   ./scripts/ci.sh              → tier-1 (slow tests deselected via pytest.ini)
#   ./scripts/ci.sh -m slow      → slow tier only
#   ./scripts/ci.sh -m "slow or not slow"  → everything
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# the dist module runs in its own multi-device process below, not here
python -m pytest -x -q --ignore=tests/test_dist.py "$@"
# multi-device tier: the distributed subsystem needs > 1 device, which a
# CPU host only has when XLA is told to fake them — run the dist module
# in its own process so the forced device count can't leak elsewhere.
# "$@" deliberately NOT forwarded: a -k/-m/path filter aimed at the main
# run would deselect everything here (pytest exit 5 → spurious CI fail)
# or re-run arbitrary tests under the forced device count.
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -x -q tests/test_dist.py
python -m compileall -q src
python scripts/check_imports.py   # every bench_*/example module imports
python scripts/check_docs.py      # README/docs symbol references resolve
# calibration smoke: the end-to-end fit CLI on a tiny design (2 graphs,
# one dim, 2 reps) incl. artifact save/reload — catches a broken fitter
# or artifact format before the full bench pass below prices with it
CAL_SMOKE="$(mktemp /tmp/calibration_smoke.XXXXXX.json)"
python -m repro.core.calibrate --fast --out "$CAL_SMOKE"
python - "$CAL_SMOKE" <<'EOF'
import sys
from repro.core.calibrate import CalibrationResult
res = CalibrationResult.load(sys.argv[1])
assert res.coef, "calibration smoke produced no coefficients"
EOF
rm -f "$CAL_SMOKE"
# obs smoke: a traced short training run must produce a Chrome-trace
# JSON the reader CLI can summarize — the acceptance path of the
# telemetry layer (spans + pack-cache counters + a logged decision)
OBS_TRACE="$(mktemp /tmp/obs_smoke.XXXXXX.json)"
python -m repro.apps.gnn --steps 2 --layers 2 --hidden 16 --trace "$OBS_TRACE" > /dev/null
python -m repro.apps.obs_report "$OBS_TRACE" --top 5
python - "$OBS_TRACE" <<'EOF'
import json, sys
t = json.load(open(sys.argv[1]))
names = {e["name"] for e in t["traceEvents"] if e["ph"] == "X"}
assert {"gnn.pack", "gnn.compile", "gnn.step"} <= names, sorted(names)
assert any("pack_cache" in m for m in t["repro_metrics"]), \
    sorted(t["repro_metrics"])
assert t["repro_decisions"], "no decision recorded in traced gnn run"
EOF
rm -f "$OBS_TRACE"
# dynamic smoke: a churn stream against a self-healing DynamicGraph must
# stay exact vs a full rebuild, surface a drift advisory, and trigger at
# least one governor re-pack — all observed through the obs counters and
# the decision log (the bounded-staleness acceptance path of the
# dynamic-graph layer, see docs/DYNAMIC.md)
python - <<'EOF'
import numpy as np
import jax.numpy as jnp
from repro import obs
from repro.core.engine import make_spmm_fn
from repro.core.pcsr import build_pcsr
from repro.data.graphs import rmat
from repro.dynamic import DynamicGraph

csr = rmat(7, 6, seed=9)
rng = np.random.default_rng(9)
with obs.tracing():
    g = DynamicGraph(csr, 16, slack=1.05, amortize_steps=10,
                     drift_threshold={"nnz": 0.05})
    for _ in range(6):
        r, c = rng.integers(0, csr.n_rows, (2, 150))
        g.insert_edges(r, c, rng.uniform(0.5, 1.5, 150).astype(np.float32))
        dcsr = g.dyn.to_csr()
        rows = np.repeat(np.arange(dcsr.n_rows), np.diff(dcsr.indptr))
        pick = rng.permutation(dcsr.nnz)[:140]
        g.delete_edges(rows[pick], dcsr.indices[pick])
    snap = obs.metrics_snapshot()
    assert sum(snap["dynamic_mutations_total"].values()) > 0, sorted(snap)
    assert sum(snap.get("dynamic_repacks_total", {}).values()) >= 1, \
        "governor never re-packed under churn"
    assert any(d.action == "repack" for d in g.decisions), \
        [d.action for d in g.decisions]
    assert any(d.advisory is not None for d in g.decisions), \
        "no drift advisory fired at a 5% nnz threshold"
# exactness after the whole governed stream: dynamic view == fresh pack
m = g.dyn.to_csr()
B = jnp.asarray(rng.standard_normal((m.n_cols, 16)), jnp.float32)
fresh = build_pcsr(m.indptr, m.indices, m.data, m.n_rows, m.n_cols,
                   g.config)
np.testing.assert_allclose(np.asarray(g.spmm(B)),
                           np.asarray(make_spmm_fn(fresh)(B)),
                           rtol=1e-6, atol=1e-6)
print("dynamic smoke: OK (repacks="
      f"{sum(d.action == 'repack' for d in g.decisions)})")
EOF
# serve smoke: a seeded bursty stream through the serving driver with
# per-request full-pipeline verification (--check) — asserts the
# bucketed forward is exact, the steering-pack cache gets hits on a
# replayed workload, and the compiled-bucket count stays below the
# batch count (the zero-recompile acceptance path, see docs/SERVING.md)
SERVE_STATS="$(mktemp /tmp/serve_smoke.XXXXXX.json)"
python -m repro.apps.serve_gnn --graph ba10k --requests 16 --check \
    --stats "$SERVE_STATS"
python - "$SERVE_STATS" <<'EOF'
import json, sys
s = json.load(open(sys.argv[1]))
assert s["checked"] == s["requests"] == 16, s
assert s["cache_hits"] > 0, "no steering-pack cache hits on the stream"
assert s["cache_hits"] + s["cache_misses"] == s["batches"], s
assert 0 < s["compiled_buckets"] <= len(s["buckets"]) < s["batches"], s
EOF
rm -f "$SERVE_STATS"
# perf-trajectory artifact: measured kernel/elementwise-pass counts for
# the fused GNN hot path + fused-vs-unfused pricing, the distributed
# per-shard config table and overlap on/off column, the skewed-corpus
# balanced-vs-uniform schedule smoke (priced + measured makespan), the
# priced-vs-measured rank correlations (small tier, pre/post fit), the
# calibrated-decider agreement/regret table, and the dynamic-graph churn
# columns (degraded-vs-fresh gap, governor trigger points, pre/post-
# repack agreement), plus the serving tier's p50/p99 latency,
# throughput, and steering-pack cache hit rate under seeded replay —
# all in one machine-readable, schema-validated BENCH_spmm.json, with
# the whole sweep traced (run.py records the trace path in the payload)
python -m benchmarks.run \
    --only fusion,dist,spmm,calibration,decider,dynamic,serve \
    --json BENCH_spmm.json --trace BENCH_trace.json
python -m repro.apps.obs_report BENCH_trace.json --top 5
python - <<'EOF'
import json
p = json.load(open("BENCH_spmm.json"))
assert p.get("trace") == "BENCH_trace.json", p.get("trace")
assert "decider" in p and "agreement" in p["decider"], sorted(p)
assert "dynamic" in p and p["dynamic"]["graphs"], sorted(p)
assert "serve" in p and p["serve"]["runs"], sorted(p)
for run in p["serve"]["runs"]:
    assert run["latency_us_p99"] >= run["latency_us_p50"] > 0, run
    assert 0.0 <= run["cache_hit_rate"] <= 1.0, run
for name, gm in p["dynamic"]["graphs"].items():
    # acceptance: after the re-pack the config in use is again the one
    # the model would pick fresh — agreement returns to baseline
    assert gm["agreement_post_repack"] == gm["agreement_fresh"] == 1, \
        (name, gm)
EOF
echo "ci: OK"
