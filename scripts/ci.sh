#!/usr/bin/env bash
# Tier-1 verify: fast test tier + bytecode-compile + import/docs checks.
#   ./scripts/ci.sh              → tier-1 (slow tests deselected via pytest.ini)
#   ./scripts/ci.sh -m slow      → slow tier only
#   ./scripts/ci.sh -m "slow or not slow"  → everything
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
python -m compileall -q src
python scripts/check_imports.py   # every bench_*/example module imports
python scripts/check_docs.py      # README/docs symbol references resolve
echo "ci: OK"
