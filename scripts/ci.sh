#!/usr/bin/env bash
# Tier-1 verify: fast test tier + bytecode-compile the whole tree.
#   ./scripts/ci.sh              → tier-1 (slow tests deselected via pytest.ini)
#   ./scripts/ci.sh -m slow      → slow tier only
#   ./scripts/ci.sh -m "slow or not slow"  → everything
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
python -m compileall -q src
echo "ci: OK"
