#!/usr/bin/env python
"""Docs check: every ``repro.*`` symbol referenced in README.md and
docs/*.md must actually exist — and the subsystem guides must COVER
their subsystem's public API.

Two kinds of references are verified:

* import statements inside fenced code blocks
  (``from repro.x import a, b`` / ``import repro.x``);
* dotted names in inline code or prose (`repro.core.engine.make_gat_message_fn`,
  including a trailing call like ``ParamSpMM(csr, ...)`` stripped) —
  resolved as the longest importable module prefix + ``getattr`` chain.

Plus the reverse direction (``COVERAGE``): a guide mapped to a package
must mention every name in that package's ``__all__`` — so a new public
symbol in ``repro.dist`` fails CI until DISTRIBUTED.md documents it,
the same bar OPERATORS.md sets for the operator surface.  A guide may
instead map to an explicit list of dotted symbols (for surfaces spread
across modules without a single ``__all__``); each symbol must both
resolve AND be mentioned by its final name.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

FENCE = re.compile(r"```(?:\w*)\n(.*?)```", re.S)
FROM_IMPORT = re.compile(r"^\s*from\s+(repro[\w.]*)\s+import\s+(.+)$", re.M)
PLAIN_IMPORT = re.compile(r"^\s*import\s+(repro[\w.]*)", re.M)
DOTTED = re.compile(r"`(repro(?:\.\w+)+)")

# guide → package whose entire ``__all__`` the guide must mention, OR an
# explicit list of dotted symbols the guide must mention by final name
COVERAGE = {
    "DISTRIBUTED.md": "repro.dist",
    # the dynamic-graph robustness surface (PR 9) — incremental PCSR,
    # governor, per-shard refresh
    "DYNAMIC.md": "repro.dynamic",
    # the inference serving surface (PR 10) — request path, shape
    # buckets, steering-pack cache
    "SERVING.md": "repro.serve",
    # the telemetry surface (PR 8) — spans/metrics/decision log/drift
    "OBSERVABILITY.md": "repro.obs",
    # the calibration surface (PR 7) — every public symbol of the
    # fit/gate subsystem must stay documented
    "CALIBRATION.md": "repro.core.calibrate",
    # the balanced-scheduling + tile-aligned-stats surface (PR 6)
    "OPERATORS.md": [
        "repro.core.balanced_capacity",
        "repro.core.pcsr.balanced_capacity",
        "repro.kernels.sddmm.ops.unpack_stats",
        "repro.kernels.sddmm.ops.pack_stats",
        "repro.kernels.sddmm.ops.normalize_from_stats",
        "repro.core.autotune.oracle_search",
        "repro.data.graphs.corpus",
    ],
}


def resolve(dotted: str) -> bool:
    """Longest importable module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
            return True
        except AttributeError:
            return False
    return False


def refs_in(text: str):
    refs = set()
    for block in FENCE.findall(text):
        for mod, names in FROM_IMPORT.findall(block):
            for name in names.split(","):
                name = name.split(" as ")[0].strip().strip("()")
                if name:
                    refs.add(f"{mod}.{name}")
        for mod in PLAIN_IMPORT.findall(block):
            refs.add(mod)
    refs.update(DOTTED.findall(text))
    return refs


def coverage_gaps(fname: str, text: str):
    """Mapped symbols the guide fails to mention (or that don't exist)."""
    spec = COVERAGE.get(fname)
    if spec is None:
        return []
    if isinstance(spec, str):                      # package __all__ form
        mod = importlib.import_module(spec)
        return [f"{spec}.{name}" for name in getattr(mod, "__all__", [])
                if not re.search(rf"\b{re.escape(name)}\b", text)]
    gaps = []                                      # explicit symbol list
    for dotted in spec:
        name = dotted.rsplit(".", 1)[-1]
        if not resolve(dotted):
            gaps.append(f"{dotted} (does not resolve)")
        elif not re.search(rf"\b{re.escape(name)}\b", text):
            gaps.append(dotted)
    return gaps


def main() -> int:
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    failures = []
    for f in files:
        text = f.read_text()
        for ref in sorted(refs_in(text)):
            if not resolve(ref):
                failures.append((f.name, f"unresolved symbol {ref}"))
        for gap in coverage_gaps(f.name, text):
            failures.append((f.name, f"public symbol {gap} undocumented"))
    for name in COVERAGE:
        if not any(f.name == name for f in files):
            failures.append((name, "coverage-mapped guide missing"))
    for fname, why in failures:
        print(f"DOCS FAIL {fname}: {why}")
    print(f"check_docs: {'FAIL' if failures else 'OK'} "
          f"({len(files)} files)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
