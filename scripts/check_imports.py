#!/usr/bin/env python
"""Compile-check: import every ``benchmarks/bench_*.py`` and
``examples/*.py`` module so refactors can't silently break the drivers
(all of them keep module-level code import-safe behind ``main()`` /
``__main__`` guards), plus the subsystem packages whose import must stay
device-independent (``repro.dist`` builds host-side plans on any
backend; only executing them needs a mesh)."""
from __future__ import annotations

import importlib
import importlib.util
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))                    # the benchmarks package
sys.path.insert(0, str(ROOT / "src"))            # repro


PACKAGES = ["repro.core", "repro.dist", "repro.dist.partition",
            "repro.dist.halo", "repro.dist.spmm",
            "repro.kernels.paramspmm.ops", "repro.kernels.sddmm.ops"]


def main() -> int:
    failures = []
    for name in PACKAGES:
        try:
            importlib.import_module(name)
        except Exception as e:                   # noqa: BLE001
            failures.append((name, e))
    for path in sorted((ROOT / "benchmarks").glob("bench_*.py")):
        name = f"benchmarks.{path.stem}"
        try:
            importlib.import_module(name)
        except Exception as e:                   # noqa: BLE001 — report all
            failures.append((name, e))
    for path in sorted((ROOT / "examples").glob("*.py")):
        name = f"examples_{path.stem}"
        try:
            spec = importlib.util.spec_from_file_location(name, path)
            mod = importlib.util.module_from_spec(spec)
            sys.modules[name] = mod
            spec.loader.exec_module(mod)
        except Exception as e:                   # noqa: BLE001
            failures.append((str(path), e))
    for name, e in failures:
        print(f"IMPORT FAIL {name}: {type(e).__name__}: {e}")
    print(f"check_imports: {'FAIL' if failures else 'OK'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
