"""Fusion benchmark: kernel/elementwise-pass counts for the GNN hot path
plus fused-vs-unfused pricing, the machine-readable core of
``BENCH_spmm.json`` (``benchmarks/run.py --json``) so the perf trajectory
of the fusion layer is tracked from PR 4 on.

Kernel-launch counts are *measured* (the Pallas dispatch is intercepted,
not assumed); the unfused elementwise-pass figures are nominal
architectural constants of the pre-fusion pipeline (keys suffixed
``_nominal``); times on a CPU host come from the analytical cost model
(interpret-mode kernel wall-clock is meaningless) plus a small measured
engine-backend training comparison fused vs unfused.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cost_model import CostModel
from repro.core.pcsr import SpMMConfig, build_pcsr, config_space
from repro.core.sparse import CSRMatrix

from .common import count_pallas_calls, emit


def _tiny_graph(n=96, density=0.12, seed=0):
    rng = np.random.default_rng(seed)
    A = ((rng.random((n, n)) < density)
         * rng.standard_normal((n, n))).astype(np.float32)
    A[n // 4:n // 2] = 0.0              # empty blocks exercise coverage
    return CSRMatrix.from_dense(A), rng


def kernel_counts():
    """Measured kernel-launch counts for the fused GNN hot paths."""
    import jax.numpy as jnp

    from repro.core.engine import ParamSpMMOperator, make_gat_message_fn

    csr, rng = _tiny_graph()
    n = csr.n_rows
    cfg = SpMMConfig(V=2, S=True, W=4)
    p = build_pcsr(csr.indptr, csr.indices, csr.data, n, n, cfg)
    gat = make_gat_message_fn(p, backend="pallas", interpret=True)
    Q = jnp.asarray(rng.standard_normal((n, 17)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((n, 17)), jnp.float32)
    Vf = jnp.asarray(rng.standard_normal((n, 15)), jnp.float32)
    gat_calls = count_pallas_calls(lambda: gat(Q, K, Vf))

    op = ParamSpMMOperator(csr, cfg, backend="pallas", interpret=True)
    B = jnp.asarray(rng.standard_normal((n, 19)), jnp.float32)
    sc = jnp.asarray(rng.random(n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(19), jnp.float32)
    gcn_calls = count_pallas_calls(
        lambda: op.fused(B, scale=sc, bias=b, activation="relu"))
    return {
        # measured (Pallas dispatch intercepted)
        "gat_forward_pallas_calls": len(gat_calls),
        "gat_forward_kernels": gat_calls,
        "gcn_aggregation_pallas_calls": len(gcn_calls),
        # nominal (architectural constants of each path, not re-measured):
        # fused = 0 interstitial passes by construction (α in-register,
        # epilogue in-kernel); the *_nominal unfused figures are what the
        # pre-fusion pipeline ran (the α normalize; scale·+bias, relu)
        "gat_forward_elementwise_passes": 0,
        "gat_forward_unfused_elementwise_passes_nominal": 1,
        "gcn_aggregation_elementwise_passes": 0,
        "gcn_aggregation_unfused_elementwise_passes_nominal": 2,
    }


def priced_configs(dim=128, heads=(1, 4)):
    """Per-config fused/unfused times and savings from the cost model."""
    csr, _ = _tiny_graph(n=1024, density=0.02, seed=1)
    cm = CostModel(csr)
    rows = []
    for cfg in config_space(dim, max_f=2):
        entry = {"config": cfg.astuple(), "dim": dim}
        for H in heads:
            entry[f"gat_fused_us_H{H}"] = cm.time(
                dim, cfg, "gat", H=H) * 1e6
            entry[f"gat_unfused_us_H{H}"] = cm.time(
                dim, cfg, "gat", H=H, fused=False) * 1e6
        entry["spmm_fused_us"] = cm.time(dim, cfg, "spmm",
                                         epilogue=True) * 1e6
        entry["spmm_unfused_us"] = cm.time(dim, cfg, "spmm",
                                           fused=False) * 1e6
        rows.append(entry)
    best_f = {H: cm.best(dim, config_space(dim, max_f=2), op="gat", H=H)[0]
              .astuple() for H in heads}
    return rows, best_f


def measured_train(steps=8):
    """Engine-backend GCN training, fused vs unfused epilogue path."""
    from repro.apps.gnn import train_gnn
    from repro.data.tasks import community_task

    task = community_task(n_blocks=6, block_size=48, seed=3)
    out = {}
    for fused in (True, False):
        t0 = time.time()
        r = train_gnn(task, model="gcn", hidden=32, n_layers=3, steps=steps,
                      spmm_mode="paramspmm", fused=fused,
                      spmm_kwargs={"reorder": False})
        out["fused" if fused else "unfused"] = {
            "seconds_per_step": r.seconds_per_step,
            "val_acc": r.val_acc,
            "wall_s": time.time() - t0,
        }
    return out


def run():
    counts = kernel_counts()
    emit("fusion/gat_fwd_pallas_calls",
         counts["gat_forward_pallas_calls"],
         "target=2;elementwise_passes=0")
    emit("fusion/gcn_agg_pallas_calls",
         counts["gcn_aggregation_pallas_calls"],
         "target=1;elementwise_passes=0")
    per_config, best_f = priced_configs()
    sav = [(e["gat_unfused_us_H1"] - e["gat_fused_us_H1"])
           for e in per_config]
    emit("fusion/gat_priced_savings_us_mean", float(np.mean(sav)),
         f"configs={len(per_config)};best_gat_cfg_per_H={best_f}")
    tr = measured_train()
    emit("fusion/gcn_train_fused", tr["fused"]["seconds_per_step"] * 1e6,
         f"unfused_us={tr['unfused']['seconds_per_step'] * 1e6:.1f};"
         f"acc={tr['fused']['val_acc']:.3f}")
    return {"kernel_counts": counts, "per_config": per_config,
            "best_gat_config_per_H": {str(k): v for k, v in best_f.items()},
            "train": tr}
