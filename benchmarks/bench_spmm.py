"""Balanced-vs-uniform SpMM schedule benchmark over the skewed corpus.

For every graph in ``corpus("skewed")`` — high-CV power-law/co-citation
stressors plus uniform-degree controls — this reports both sides of the
B-mode acceptance story:

* **priced** makespan: ``CostModel.best`` over the full config space vs
  the uniform-only (``B=False``) subspace, so the row records whether the
  cost model *selects* the balanced schedule and how much it thinks it
  saves;
* **measured** makespan: median engine wall-clock with the *schedule
  isolated* — the selected config measured against the SAME ⟨W, F, V⟩
  with the B bit toggled, so the comparison never conflates the chunk
  schedule with a blocking change (the engine's per-slot cost differs
  across V, which would pollute a best-vs-best measurement).  The engine
  gathers every slot (padding included), so its time scales with total
  slots C·K — exactly the quantity the balanced packer minimizes —
  making it a faithful CPU-host proxy for the TPU kernel's slot-bound
  makespan.

Structured metrics feed the ``"spmm"`` section of ``BENCH_spmm.json``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import time_fn
from repro.core.cost_model import CostModel
from repro.core.engine import _engine
from repro.core.pcsr import build_pcsr, config_space

from .common import bench_corpus, emit

DIM = 32
REPS = 7


def _measure(csr, cfg, dim: int, rng) -> tuple[float, int]:
    """Median engine seconds (and slot count) for one SpMM on ``cfg``'s
    steering arrays."""
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, cfg)
    t = p.steering()
    dim_pad = -(-dim // cfg.dblk) * cfg.dblk
    B = jnp.asarray(rng.standard_normal((csr.n_cols, dim_pad)), jnp.float32)
    sec = time_fn(
        lambda: _engine(t["colidx"], t["lrow"], t["trow"], t["vals"], B,
                        V=cfg.V, R=cfg.R, K=p.K, n_blocks=p.n_blocks,
                        n_rows=p.n_rows), reps=REPS, warmup=2)
    return sec, p.num_slots


def run():
    """Balanced-vs-uniform priced + measured makespan per skewed graph."""
    metrics: dict = {"dim": DIM, "graphs": {}}
    rng = np.random.default_rng(0)
    for spec in bench_corpus("skewed"):
        csr = spec.csr
        deg = np.diff(csr.indptr)
        cv = float(deg.std() / max(deg.mean(), 1e-12))
        cm = CostModel(csr)
        space = config_space(DIM)
        best, t_best = cm.best(DIM, space)
        best_uni, t_uni = cm.best(DIM, [c for c in space if not c.B])
        # schedule-isolated measurement: best's ⟨W, F, V⟩, B toggled
        cfg_b = dataclasses.replace(best, S=True, B=True)
        cfg_u = dataclasses.replace(best, B=False)
        m_bal, slots_b = _measure(csr, cfg_b, DIM, rng)
        m_uni, slots_u = _measure(csr, cfg_u, DIM, rng)
        emit(f"spmm/{spec.name}/balanced" if best.B
             else f"spmm/{spec.name}/uniform",
             (m_bal if best.B else m_uni) * 1e6,
             f"family={spec.family};cv={cv:.2f};"
             f"priced_us={t_best * 1e6:.1f};"
             f"priced_uniform_us={t_uni * 1e6:.1f};"
             f"cfg={best.astuple()};cfg_uniform={best_uni.astuple()};"
             f"priced_gain={t_uni / max(t_best, 1e-12):.3f};"
             f"measured_balanced_us={m_bal * 1e6:.1f};"
             f"measured_uniform_us={m_uni * 1e6:.1f};"
             f"measured_gain={m_uni / max(m_bal, 1e-12):.3f};"
             f"slots_balanced={slots_b};slots_uniform={slots_u}")
        metrics["graphs"][spec.name] = {
            "family": spec.family,
            "degree_cv": cv,
            "nnz": int(csr.nnz),
            "balanced_selected": bool(best.B),
            "best_config": best.astuple(),
            "best_uniform_config": best_uni.astuple(),
            "priced_best_us": t_best * 1e6,
            "priced_uniform_us": t_uni * 1e6,
            "measured_balanced_us": m_bal * 1e6,
            "measured_uniform_us": m_uni * 1e6,
            "slots_balanced": int(slots_b),
            "slots_uniform": int(slots_u),
        }
    return metrics
