"""Priced-vs-measured rank correlation per corpus tier + calibration fit.

The paper's adaptivity claim rests on the label source ranking configs
the way real hardware does.  This benchmark measures exactly that, per
corpus tier:

* build the measured design (``calibrate.build_design``: every config of
  the space timed on the jit'd engine via ``autotune.time_fn``, features
  priced from the analytic grid extents);
* fit the cost-model constants on the first tier's design
  (``calibrate.fit`` — NNLS on relative residuals);
* record Spearman ρ between priced and measured times **pre**-calibration
  (hand-set constants) and **post**-calibration (fitted coefficients) —
  pooled per tier and per graph — plus the fitted coefficients.

Rows land in BENCH_spmm.json via ``run.py --json`` (key
``calibration``), so every future "X× faster" claim can point at the
rank correlation of the prices it was selected by.  Tiers after the
first are scored *out-of-sample* — the fit generalization claim.

Defaults are the CI smoke: small tier, 2 reps, spmm only.  The full
pass (``--tiers small,skewed,large --reps 3 --ops spmm,sddmm``) is the
one to run on new hardware — see docs/CALIBRATION.md.
"""
from __future__ import annotations

import numpy as np

# per-tier nnz ceiling for the measured subset (CPU wall-clock budget);
# tiers not listed fall back to the "small" ceiling
TIER_MAX_NNZ = {"small": 300_000, "skewed": 300_000,
                "bench": 300_000, "large": 3_000_000}


def _tier_rho(samples, cal, spearman):
    """Pooled + per-graph pre/post Spearman ρ of one tier's design."""
    y = np.array([s.measured for s in samples])
    pre = np.array([s.priced for s in samples])
    post = cal.predict(samples)
    per_graph = {}
    for gname in sorted({s.graph for s in samples}):
        idx = [i for i, s in enumerate(samples) if s.graph == gname]
        per_graph[gname] = {
            "rho_pre": spearman(pre[idx], y[idx]),
            "rho_post": spearman(post[idx], y[idx]),
            "n": len(idx),
        }
    return {"rho_pre": spearman(pre, y), "rho_post": spearman(post, y),
            "n": len(samples), "per_graph": per_graph}


def run(tiers=("small",), reps: int = 2, dims=(32, 64), ops=("spmm",),
        max_graphs: int = 5, heads: int = 1):
    from benchmarks.common import emit
    from repro.core.calibrate import build_design, fit, spearman
    from repro.data.graphs import corpus

    metrics: dict = {"reps": reps, "dims": list(dims), "ops": list(ops),
                     "tiers": {}}
    designs = {}
    for tier in tiers:
        ceiling = TIER_MAX_NNZ.get(tier, TIER_MAX_NNZ["small"])
        graphs = [g for g in corpus(tier) if g.csr.nnz <= ceiling]
        if len(graphs) > max_graphs:
            emit(f"calibration/{tier}/subset", 0.0,
                 f"kept={max_graphs};dropped={len(graphs) - max_graphs}")
            graphs = graphs[:max_graphs]
        designs[tier] = build_design(graphs, dims=dims, ops=ops, reps=reps,
                                     H=heads)

    # fit on the first tier's design; later tiers score out-of-sample
    fit_tier = tiers[0]
    cal = fit(designs[fit_tier], meta={"tier": fit_tier, "reps": reps,
                                       "dims": list(dims),
                                       "ops": list(ops)})
    metrics["fit"] = cal.to_dict()
    for op, c in cal.coef.items():
        emit(f"calibration/fit/{op}", 0.0,
             ";".join(f"{k}={v:.4e}" for k, v in c.items())
             + f";fit_tier={fit_tier}")

    for tier in tiers:
        tm = _tier_rho(designs[tier], cal, spearman)
        tm["in_sample"] = tier == fit_tier
        metrics["tiers"][tier] = tm
        emit(f"calibration/{tier}/rho", 0.0,
             f"rho_pre={tm['rho_pre']:.3f};rho_post={tm['rho_post']:.3f};"
             f"n={tm['n']};in_sample={int(tm['in_sample'])}")
        for gname, gm in tm["per_graph"].items():
            emit(f"calibration/{tier}/{gname}", 0.0,
                 f"rho_pre={gm['rho_pre']:.3f};"
                 f"rho_post={gm['rho_post']:.3f};n={gm['n']}")
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiers", default="small",
                    help="comma-separated corpus tiers "
                    "(small,skewed,bench,large)")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--dims", default="32,64")
    ap.add_argument("--ops", default="spmm")
    ap.add_argument("--max-graphs", type=int, default=5)
    ap.add_argument("--heads", type=int, default=1)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tiers=tuple(args.tiers.split(",")), reps=args.reps,
        dims=tuple(int(d) for d in args.dims.split(",")),
        ops=tuple(args.ops.split(",")), max_graphs=args.max_graphs,
        heads=args.heads)
