"""Paper Table 1: throughput under V ∈ {1,2,3} with zero-padding ratios —
the vectorized-blocking/data-locality trade-off.

Primary numbers are TPU cost-model throughput (the kernel's deployment
target: V=2 wins by halving B-row gather traffic when PR_2 is low).  The
measured CPU-engine time is reported alongside; on CPU the scatter-add
dominates and hides the gather saving — a documented backend artifact
(DESIGN.md §7)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.engine import engine_spmm
from repro.core.autotune import time_fn
from repro.core.pcsr import SpMMConfig, build_pcsr
from .common import bench_corpus, emit, gflops, subset

DIM = 32
# clone graphs = coPapers analogues (V=2 wins, low PR_2);
# shuffled graphs = sx-* analogues (V=1 wins, padding dominates)
GRAPHS = ["clones4000", "clones16000", "rmat12_sh", "er16000_sh"]


def run():
    gs = {g.name: g for g in bench_corpus()}
    rng = np.random.default_rng(0)
    for name in GRAPHS:
        g = gs[name]
        cm = CostModel(g.csr)
        B = jnp.asarray(rng.standard_normal((g.csr.n_cols, DIM)),
                        jnp.float32)
        for V in (1, 2, 3):
            cfg = SpMMConfig(V=V, S=False, F=1, W=max(1, 16 // V))
            p = build_pcsr(g.csr.indptr, g.csr.indices, g.csr.data,
                           g.csr.n_rows, g.csr.n_cols, cfg)
            t_model = cm.time(DIM, cfg)
            t_cpu = time_fn(engine_spmm, p, B, reps=3)
            emit(f"table1/{name}/V{V}", t_model * 1e6,
                 f"tpu_gflops={gflops(g.csr, DIM, t_model):.1f};"
                 f"pr={p.padding_ratio:.3f};cpu_us={t_cpu*1e6:.0f}")
