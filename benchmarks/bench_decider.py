"""Paper Table 5: SpMM-decider prediction quality — normalized performance
of predicted vs oracle configurations, with random configuration as the
baseline.  80/20 split by graph; labels from the TPU cost model over the
full ⟨W,F,V,S⟩ space."""
from __future__ import annotations

import os
import pickle

from repro.apps.decider_train import DIMS, build_dataset, train_eval
from .common import bench_corpus, emit

DECIDER_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "decider.pkl")


CALIBRATION_PATH = os.path.join(os.path.dirname(__file__), "..", "configs",
                                "calibration_cpu_host.json")


def run(save=True):
    ds = build_dataset(bench_corpus(), dims=DIMS)
    ev = train_eval(ds)
    for dim, (pred, rnd) in ev.per_dim.items():
        emit(f"table5/dim{dim}", 0.0,
             f"pred={100*pred:.2f}%;rnd={100*rnd:.2f}%")
    emit("table5/overall", 0.0,
         f"pred={100*ev.overall_pred:.2f}%;rnd={100*ev.overall_rnd:.2f}%")
    if save:
        os.makedirs(os.path.dirname(DECIDER_PATH), exist_ok=True)
        ev.decider.save(DECIDER_PATH)
    return ev.decider


def run_calibrated(scale: str = "small", dims=(32, 64, 128),
                   calibration=None, seed: int = 0) -> dict:
    """Retrain the decider on *calibrated* labels (the fitted-to-host
    cost model, ``decider_train --calibration``) and record the
    decider-vs-oracle quality that makes adaptivity claims observable:
    **agreement** (how often the predicted config prices at the
    calibrated oracle's best time — price ties count as agreement)
    and **regret** (t_pred/t_best when it does not).
    Emits ``decider/...`` rows and returns the structured metrics dict
    ``run.py --json`` folds into BENCH_spmm.json as the ``decider``
    extras section."""
    from repro.data.graphs import corpus

    path = calibration or CALIBRATION_PATH
    ds = build_dataset(corpus(scale), dims=dims, calibration=path)
    ev = train_eval(ds, seed=seed)
    for dim, q in sorted(ev.per_dim_quality.items()):
        emit(f"decider/dim{dim}", 0.0,
             f"agreement={q['agreement']:.3f};"
             f"mean_regret={q['mean_regret']:.3f};"
             f"pred_norm={ev.per_dim[dim][0]:.3f}")
    emit("decider/overall", 0.0,
         f"agreement={ev.agreement:.3f};mean_regret={ev.mean_regret:.3f};"
         f"max_regret={ev.max_regret:.3f};"
         f"calibration={os.path.basename(path)}")
    return {
        "calibration": os.path.basename(path),
        "scale": scale, "dims": list(dims),
        "agreement": ev.agreement,
        "mean_regret": ev.mean_regret,
        "max_regret": ev.max_regret,
        "overall_pred_norm": ev.overall_pred,
        "overall_rnd_norm": ev.overall_rnd,
        "per_dim": {str(d): dict(q, pred_norm=ev.per_dim[d][0])
                    for d, q in sorted(ev.per_dim_quality.items())},
    }
