"""Paper Table 5: SpMM-decider prediction quality — normalized performance
of predicted vs oracle configurations, with random configuration as the
baseline.  80/20 split by graph; labels from the TPU cost model over the
full ⟨W,F,V,S⟩ space."""
from __future__ import annotations

import os
import pickle

from repro.apps.decider_train import DIMS, build_dataset, train_eval
from .common import bench_corpus, emit

DECIDER_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "decider.pkl")


def run(save=True):
    ds = build_dataset(bench_corpus(), dims=DIMS)
    ev = train_eval(ds)
    for dim, (pred, rnd) in ev.per_dim.items():
        emit(f"table5/dim{dim}", 0.0,
             f"pred={100*pred:.2f}%;rnd={100*rnd:.2f}%")
    emit("table5/overall", 0.0,
         f"pred={100*ev.overall_pred:.2f}%;rnd={100*ev.overall_rnd:.2f}%")
    if save:
        os.makedirs(os.path.dirname(DECIDER_PATH), exist_ok=True)
        ev.decider.save(DECIDER_PATH)
    return ev.decider
