"""Distributed SpMM/GAT scaling, per-partition configs, overlap column.

Three claims measured (the cross-shard form of the paper's adaptivity
argument):

* **per-partition configs differ** — on a power-law graph the
  balanced-nnz shards have different density/CV, so ``CostModel.best``
  picks different ⟨W,F,V,S⟩ per shard (priced per ``--heads`` for the
  attention pipeline); the table rows record each shard's choice plus
  its predicted time, and ``adaptive_gain`` compares the predicted
  makespan (max over shards) against forcing the single best *global*
  config onto every shard — the one-size-fits-all failure mode,
  quantified.
* **halo/compute overlap** — per partition count, the ``overlap`` rows
  price the decomposition (local/halo sub-SpMM times + the
  ``halo_exchange_cost`` wire time → serialized vs overlapped schedule)
  and, when the host mesh is big enough, *measure* ``dist_spmm`` with
  ``overlap=False`` vs ``overlap=True`` — the on/off column.
* **scaling** — wall-clock of ``dist_spmm`` for every partition count
  the host's device mesh can hold (CPU: run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); partition
  counts beyond the device count fall back to cost-model makespans so
  the curve is always complete.

``run`` returns the structured metrics dict ``benchmarks/run.py --json``
folds into ``BENCH_spmm.json`` (the perf-trajectory artifact ci.sh
archives), so dist perf is tracked alongside kernel perf.
"""
from __future__ import annotations

import numpy as np

from repro.core import CostModel, config_space
from repro.core.cost_model import (halo_exchange_cost,
                                   overlap_exposed_cost)
from repro.data.graphs import er, rmat


def _predicted_makespan(graph, configs) -> float:
    """Cost-model makespan: slowest shard under the given configs."""
    return max(CostModel(s.csr).time(graph.dim, c)
               for s, c in zip(graph.part.shards, configs))


def _overlap_prediction(g_ov) -> dict:
    """Priced overlap schedule: per shard, local/halo sub-SpMM times +
    the gather wire time → max over shards of serialized vs overlapped."""
    serial = hidden = 0.0
    exch = halo_exchange_cost(g_ov.halo.gathered_rows, g_ov.dim)
    for (loc, hal), (lc, hc) in zip(g_ov._split_csrs,
                                    g_ov.overlap_configs):
        t_loc = CostModel(loc).time(g_ov.dim, lc)
        t_hal = CostModel(hal).time(g_ov.dim, hc)
        serial = max(serial, t_loc + t_hal + exch)
        hidden = max(hidden, overlap_exposed_cost(t_loc, t_hal, exch))
    return {"exchange_us": exch * 1e6, "serialized_us": serial * 1e6,
            "overlapped_us": hidden * 1e6,
            "predicted_gain": serial / max(hidden, 1e-12)}


def overlap_row(name: str, n_parts: int, ov: dict) -> tuple:
    """The ``(name, us_per_call, derived)`` of the overlap on/off row —
    the one schema ``tests/test_bench_schema.py`` pins.

    At a single partition there is no halo to hide (every source row is
    local), so the overlap decomposition only adds a second kernel pass
    and its dispatch overhead: measuring it records "overlap costs 1.5×"
    where the feature simply does not apply.  The ``skipped`` annotation
    replaces that artifact row — with ``us_per_call=None``: a skipped
    row must not carry ANY timing (an off-schedule time next to
    ``skipped`` reads as a measured overlap time downstream); real
    on/off measurements only exist for ``n_parts > 1``.
    """
    if ov.get("skipped"):
        return (f"dist/{name}/p{n_parts}/overlap", None,
                f"skipped={ov['skipped']};"
                f"exchange_us={ov['exchange_us']:.1f}")
    return (f"dist/{name}/p{n_parts}/overlap", ov["measured_on_us"],
            f"off_us={ov['measured_off_us']:.1f};"
            f"predicted_gain={ov['predicted_gain']:.3f};"
            f"exchange_us={ov['exchange_us']:.1f}")


def run(dim: int = 64, parts=(1, 2, 4, 8), heads: int = 1):
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit
    from repro.core.autotune import time_fn
    from repro.dist import DistGraph, dist_gat_message, dist_spmm

    graphs = [("rmat13", rmat(13, 8, seed=1)), ("er8k", er(8192, 8, seed=2))]
    ndev = jax.device_count()
    rng = np.random.default_rng(0)
    metrics: dict = {"dim": dim, "heads": heads, "graphs": {}}

    for name, csr in graphs:
        B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
        global_cfg, _ = CostModel(csr).best(dim, config_space(dim), H=heads)
        gm: dict = {"parts": {}}
        metrics["graphs"][name] = gm
        for n_parts in parts:
            if n_parts > csr.n_rows:
                continue
            # beyond the device count only the host-side plan (partition
            # + per-shard configs) is exercised — DistGraph touches no
            # devices until its first call
            measurable = n_parts <= ndev
            g = DistGraph(csr, dim, n_parts, strategy="balanced",
                          heads=heads)
            for i, (s, c) in enumerate(zip(g.part.shards, g.configs)):
                w, f, v, sw, bal = c.astuple()
                emit(f"dist/{name}/p{n_parts}/shard{i}",
                     g.predicted_times[i] * 1e6,
                     f"rows={s.n_local_rows};nnz={s.csr.nnz};"
                     f"halo={s.n_halo};W={w};F={f};V={v};S={int(sw)};"
                     f"B={int(bal)};H={heads}")
            adaptive = _predicted_makespan(g, g.configs)
            uniform = _predicted_makespan(g, [global_cfg] * n_parts)
            emit(f"dist/{name}/p{n_parts}/adaptive_gain", adaptive * 1e6,
                 f"uniform_us={uniform * 1e6:.1f};"
                 f"gain={uniform / max(adaptive, 1e-12):.3f};"
                 f"n_unique_cfgs={len(set(g.configs))}")
            pm: dict = {
                "adaptive_us": adaptive * 1e6,
                "uniform_us": uniform * 1e6,
                "n_unique_cfgs": len(set(g.configs)),
                "shard_configs": [c.astuple() for c in g.configs],
            }
            gm["parts"][n_parts] = pm

            # ------------------------------------- overlap on/off column
            g_ov = DistGraph(csr, dim, n_parts, strategy="balanced",
                             heads=heads, overlap=True)
            ov = _overlap_prediction(g_ov)
            pm["overlap"] = ov
            if measurable:
                t_off = time_fn(lambda b: dist_spmm(g, b), B, reps=3)
                ov["measured_off_us"] = t_off * 1e6
                if n_parts == 1:
                    ov["skipped"] = "p1_no_halo"
                else:
                    t_on = time_fn(lambda b: dist_spmm(g_ov, b), B, reps=3)
                    ov["measured_on_us"] = t_on * 1e6
                emit(*overlap_row(name, n_parts, ov))
                pm["measured_us"] = t_off * 1e6
                emit(f"dist/{name}/p{n_parts}/measured", t_off * 1e6,
                     f"devices={ndev}")
            else:
                emit(f"dist/{name}/p{n_parts}/overlap_predicted",
                     ov["overlapped_us"],
                     f"serialized_us={ov['serialized_us']:.1f};"
                     f"predicted_gain={ov['predicted_gain']:.3f}")
                emit(f"dist/{name}/p{n_parts}/predicted_makespan",
                     adaptive * 1e6, f"needs_devices={n_parts}")

            # ----------------------------- multi-head distributed GAT
            if heads > 1 and measurable and name == "rmat13":
                gg = DistGraph(csr, dim, n_parts, strategy="balanced",
                               op="gat", heads=heads)
                d_h = max(1, dim // heads)
                Q = jnp.asarray(rng.standard_normal(
                    (heads, csr.n_rows, d_h)), jnp.float32)
                t_gat = time_fn(
                    lambda q: dist_gat_message(gg, q, Q, Q), Q, reps=2)
                emit(f"dist/{name}/p{n_parts}/gat_h{heads}",
                     t_gat * 1e6, f"d_head={d_h}")
                pm["gat_measured_us"] = t_gat * 1e6
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--heads", type=int, default=1,
                    help="head count the per-shard configs are priced "
                    "for (and, with a mesh, the measured dist GAT)")
    ap.add_argument("--dim", type=int, default=64)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(dim=args.dim, heads=args.heads)
