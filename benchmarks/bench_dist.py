"""Distributed SpMM scaling + per-partition adaptive-config table.

Two claims measured (the cross-shard form of the paper's adaptivity
argument):

* **per-partition configs differ** — on a power-law graph the
  balanced-nnz shards have different density/CV, so ``CostModel.best``
  picks different ⟨W,F,V,S⟩ per shard; the table rows record each
  shard's choice plus its predicted time, and ``adaptive_gain`` compares
  the predicted makespan (max over shards) against forcing the single
  best *global* config onto every shard — the one-size-fits-all failure
  mode, quantified.
* **scaling** — wall-clock of `dist_spmm` for every partition count the
  host's device mesh can hold (CPU: run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); partition
  counts beyond the device count fall back to cost-model makespans so
  the curve is always complete.
"""
from __future__ import annotations

import numpy as np

from repro.core import CostModel, config_space
from repro.data.graphs import er, rmat


def _predicted_makespan(graph, configs) -> float:
    """Cost-model makespan: slowest shard under the given configs."""
    return max(CostModel(s.csr).time(graph.dim, c)
               for s, c in zip(graph.part.shards, configs))


def run(dim: int = 64, parts=(1, 2, 4, 8)):
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit
    from repro.core.autotune import time_fn
    from repro.dist import DistGraph, dist_spmm

    graphs = [("rmat13", rmat(13, 8, seed=1)), ("er8k", er(8192, 8, seed=2))]
    ndev = jax.device_count()
    rng = np.random.default_rng(0)

    for name, csr in graphs:
        B = jnp.asarray(rng.standard_normal((csr.n_rows, dim)), jnp.float32)
        global_cfg, _ = CostModel(csr).best(dim, config_space(dim))
        for n_parts in parts:
            if n_parts > csr.n_rows:
                continue
            # beyond the device count only the host-side plan (partition
            # + per-shard configs) is exercised — DistGraph touches no
            # devices until its first call
            measurable = n_parts <= ndev
            g = DistGraph(csr, dim, n_parts, strategy="balanced")
            for i, (s, c) in enumerate(zip(g.part.shards, g.configs)):
                w, f, v, sw = c.astuple()
                emit(f"dist/{name}/p{n_parts}/shard{i}",
                     g.predicted_times[i] * 1e6,
                     f"rows={s.n_local_rows};nnz={s.csr.nnz};"
                     f"halo={s.n_halo};W={w};F={f};V={v};S={int(sw)}")
            adaptive = _predicted_makespan(g, g.configs)
            uniform = _predicted_makespan(g, [global_cfg] * n_parts)
            emit(f"dist/{name}/p{n_parts}/adaptive_gain", adaptive * 1e6,
                 f"uniform_us={uniform * 1e6:.1f};"
                 f"gain={uniform / max(adaptive, 1e-12):.3f};"
                 f"n_unique_cfgs={len(set(g.configs))}")
            if measurable:
                t = time_fn(lambda b: dist_spmm(g, b), B, reps=3)
                emit(f"dist/{name}/p{n_parts}/measured", t * 1e6,
                     f"devices={ndev}")
            else:
                emit(f"dist/{name}/p{n_parts}/predicted_makespan",
                     adaptive * 1e6, f"needs_{n_parts}_devices")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
