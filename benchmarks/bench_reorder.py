"""Paper Table 6: graph-reordering ablation — cuSPARSE / ParamSpMM with
and without (Rabbit-style) reordering, speedups normalized to
cuSPARSE-without-reordering.  Reordering lowers PR_2 / bandwidth, which
ParamSpMM's V=2 blocking exploits better than the static vendor kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import oracle_search, time_fn
from repro.core.baselines import make_cusparse_analog
from repro.core.engine import engine_spmm
from repro.core.features import extract_features
from repro.core.pcsr import build_pcsr
from repro.core.reorder import apply_reorder, rabbit_reorder
from .common import bench_corpus, emit, subset

DIMS = (32, 64, 128)


def run():
    """TPU cost model primary (see bench_speedups docstring); the vendor
    static config is priced on the same model so the four quantities are
    comparable the way the paper's Table 6 is."""
    from repro.core.cost_model import CostModel
    from repro.core.pcsr import config_space
    from .bench_speedups import CUSPARSE_CFG

    names = ["clones16000_sh", "clones4000_sh", "rmat13_sh", "sbm32x256_sh"]
    gs = {g.name: g for g in bench_corpus()}
    for name in names:
        if name not in gs:
            continue
        wor = gs[name].csr
        perm = rabbit_reorder(wor)
        wr = apply_reorder(wor, perm)
        pr_wor = extract_features(wor).as_dict()["pr_2"]
        pr_wr = extract_features(wr).as_dict()["pr_2"]
        cm_wor, cm_wr = CostModel(wor), CostModel(wr)
        for dim in DIMS:
            t_cus_wor = cm_wor.time(dim, CUSPARSE_CFG)
            t_cus = cm_wr.time(dim, CUSPARSE_CFG)
            t_par_wor = cm_wor.best(dim, config_space(dim))[1]
            t_par = cm_wr.best(dim, config_space(dim))[1]
            emit(f"table6/{name}/dim{dim}", t_par * 1e6,
                 f"cusparse={t_cus_wor/t_cus:.2f}x;"
                 f"paramspmm_wor={t_cus_wor/t_par_wor:.2f}x;"
                 f"paramspmm={t_cus_wor/t_par:.2f}x;"
                 f"pr2={pr_wor:.3f}->{pr_wr:.3f}")
