"""Paper Table 4 / Fig. 4: ParamSpMM speedups over the baseline families.

PRIMARY: the TPU cost model prices every method's kernel configuration on
the deployment target (the paper measures its CUDA kernels on the
deployment GPU — on this CPU-only host the jitted engine is an emulation,
while vendor BCOO is a tuned native kernel, so raw CPU wall-clock compares
host-kernel quality, not the paper's adaptivity claim).  Baseline-analog
configs: cuSPARSE = one fixed input-agnostic config; GE-SpMM = static + F
scaled with dim; GNNAdvisor = heuristic always-balance; DA-SpMM = best of
its reduced {S,W} space.  SECONDARY: measured CPU wall-clock vs the BCOO
vendor path is still emitted per graph for transparency."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import oracle_search, time_fn
from repro.core.baselines import (daspmm_space, gnnadvisor_config,
                                  make_cusparse_analog)
from repro.core.cost_model import CostModel
from repro.core.engine import engine_spmm
from repro.core.features import extract_features
from repro.core.pcsr import SpMMConfig, build_pcsr, LANES
from .common import bench_corpus, emit, subset

DIMS = (32, 64, 128)
CUSPARSE_CFG = SpMMConfig(V=1, S=False, F=1, W=16)   # fixed vendor config


def _gespmm_config(dim):
    return SpMMConfig(V=1, S=False, F=min(4, max(1, -(-dim // LANES))),
                      W=16)


def run(decider=None):
    gs = subset(bench_corpus(), k=10)
    rng = np.random.default_rng(0)
    agg = {m: {d: [] for d in DIMS} for m in
           ("gespmm", "gnnadvisor", "daspmm", "paramspmm")}
    for g in gs:
        cm = CostModel(g.csr)
        feats = extract_features(g.csr)
        for dim in DIMS:
            t_cus = cm.time(dim, CUSPARSE_CFG)
            t_ge = cm.time(dim, _gespmm_config(dim))
            t_gnna = cm.time(dim, gnnadvisor_config(dim))
            t_da = min(cm.time(dim, c) for c in daspmm_space(dim))
            cfg = (decider.predict(feats, dim) if decider
                   else oracle_search(g.csr, dim, mode="model",
                                      cm=cm).best_config)
            t_par = cm.time(dim, cfg)
            # secondary: measured CPU of our engine vs vendor BCOO
            B = jnp.asarray(rng.standard_normal((g.csr.n_cols, dim)),
                            jnp.float32)
            p = build_pcsr(g.csr.indptr, g.csr.indices, g.csr.data,
                           g.csr.n_rows, g.csr.n_cols, cfg)
            cpu_par = time_fn(engine_spmm, p, B, reps=2)
            cpu_cus = time_fn(make_cusparse_analog(g.csr), B, reps=2)
            emit(f"table4/{g.name}/dim{dim}", t_par * 1e6,
                 f"vs_cusparse={t_cus/t_par:.2f};vs_gespmm={t_ge/t_par:.2f};"
                 f"vs_gnnadvisor={t_gnna/t_par:.2f};"
                 f"vs_daspmm={t_da/t_par:.2f};cfg={cfg.astuple()};"
                 f"cpu_engine_vs_bcoo={cpu_cus/cpu_par:.2f}")
            for m, t in (("gespmm", t_ge), ("gnnadvisor", t_gnna),
                         ("daspmm", t_da), ("paramspmm", t_par)):
                agg[m][dim].append(t_cus / t)
    for m, per_dim in agg.items():
        for d, v in per_dim.items():
            emit(f"table4/avg_speedup_vs_cusparse/{m}/dim{d}", 0.0,
                 f"speedup={np.mean(v):.2f}x")
        allv = [x for v in per_dim.values() for x in v]
        emit(f"table4/avg_speedup_vs_cusparse/{m}/all", 0.0,
             f"speedup={np.mean(allv):.2f}x")
