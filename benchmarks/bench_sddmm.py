"""SDDMM engine timing across ⟨W,F,V,S⟩ configs + fused GAT message step.

Per graph: engine SDDMM under the cost-model-best SpMM config vs. a
representative sweep, plus one fused SDDMM→softmax→SpMM (GAT message)
call — the pair every attention-GNN layer issues per step."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import time_fn
from repro.core.cost_model import CostModel
from repro.core.engine import engine_sddmm, make_gat_message_fn
from repro.core.pcsr import SpMMConfig, build_pcsr, config_space
from .common import bench_corpus, emit

DIM = 64
GRAPHS = ["sbm32x256", "rmat13", "er16000", "grid128"]
SWEEP = [SpMMConfig(V=1, S=False, W=8), SpMMConfig(V=2, S=False, W=4),
         SpMMConfig(V=1, S=True, W=8), SpMMConfig(V=2, S=True, W=8)]


def run():
    rng = np.random.default_rng(0)
    gs = {g.name: g for g in bench_corpus()}
    for name in GRAPHS:
        if name not in gs:
            continue
        csr = gs[name].csr
        Q = jnp.asarray(rng.standard_normal((csr.n_rows, DIM)), jnp.float32)
        K = jnp.asarray(rng.standard_normal((csr.n_cols, DIM)), jnp.float32)
        Vf = jnp.asarray(rng.standard_normal((csr.n_cols, DIM)), jnp.float32)

        best, _ = CostModel(csr).best(DIM, config_space(DIM))
        for cfg in [best] + [c for c in SWEEP if c != best]:
            p = build_pcsr(csr.indptr, csr.indices, csr.data,
                           csr.n_rows, csr.n_cols, cfg)
            t = time_fn(lambda: engine_sddmm(p, Q, K), reps=3)
            tag = "best" if cfg == best else "cfg"
            emit(f"sddmm/{name}/{tag}{cfg.astuple()}", t * 1e6,
                 f"nnz={csr.nnz};slots={p.num_slots};"
                 f"fill={p.slot_fill:.2f}")

        p = build_pcsr(csr.indptr, csr.indices, csr.data,
                       csr.n_rows, csr.n_cols, best)
        msg = make_gat_message_fn(p, backend="engine")
        t = time_fn(lambda: msg(Q, K, Vf), reps=3)
        emit(f"gat_message/{name}", t * 1e6,
             f"cfg={best.astuple()};nnz={csr.nnz}")
