"""SDDMM / fused GAT-attention timings across ⟨W,F,V,S⟩ configs.

Corpus scale (jitted JAX engine — the CPU-meaningful numbers): engine
SDDMM under the cost-model-best config vs. a representative sweep, the
unfused attention front half (SDDMM + segment softmax), and the full GAT
message step single-head and 4-head, with the analytical ``sddmm_cost``
estimate emitted next to the measurement so cost-model drift is visible.

Kernel scale (interpret-mode Pallas is ~100µs/grid-step on CPU, so a
small graph): the fused ``sddmm_softmax`` kernel vs. its unfused engine
oracle — the pair whose HBM-round-trip difference the fusion exists to
remove; on real TPUs this comparison is the one to re-run first."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import time_fn
from repro.core.cost_model import CostModel
from repro.core.engine import (_slot_rows, edge_softmax, engine_sddmm,
                               make_gat_message_fn)
from repro.core.pcsr import SpMMConfig, build_pcsr, config_space
from repro.data.graphs import rmat
from repro.kernels.sddmm import sddmm_softmax
from .common import bench_corpus, emit

DIM = 64
HEADS = 4
GRAPHS = ["sbm32x256", "rmat13", "er16000", "grid128"]
SWEEP = [SpMMConfig(V=1, S=False, W=8), SpMMConfig(V=2, S=False, W=4),
         SpMMConfig(V=1, S=True, W=8), SpMMConfig(V=2, S=True, W=8)]


def _unfused_softmax_fn(p, Q, K):
    cfg = p.config
    arrs = p.to_jax()
    mask = arrs["vals"] != 0
    rows = _slot_rows(arrs["lrow"], arrs["trow"], V=cfg.V, R=cfg.R, K=p.K)

    @jax.jit
    def fn():
        s = engine_sddmm(p, Q, K)
        s = jax.nn.leaky_relu(s / jnp.sqrt(jnp.float32(Q.shape[-1])), 0.2)
        return edge_softmax(s, mask, rows, p.n_blocks * cfg.R)

    return fn


def run():
    rng = np.random.default_rng(0)
    gs = {g.name: g for g in bench_corpus()}
    for name in GRAPHS:
        if name not in gs:
            continue
        csr = gs[name].csr
        cm = CostModel(csr)
        Q = jnp.asarray(rng.standard_normal((csr.n_rows, DIM)), jnp.float32)
        K = jnp.asarray(rng.standard_normal((csr.n_cols, DIM)), jnp.float32)
        Vf = jnp.asarray(rng.standard_normal((csr.n_cols, DIM)), jnp.float32)

        best, _ = cm.best(DIM, config_space(DIM))
        for cfg in [best] + [c for c in SWEEP if c != best]:
            p = build_pcsr(csr.indptr, csr.indices, csr.data,
                           csr.n_rows, csr.n_cols, cfg)
            t = time_fn(lambda: engine_sddmm(p, Q, K), reps=3)
            tag = "best" if cfg == best else "cfg"
            model_us = cm.cost(DIM, cfg, op="sddmm").total * 1e6
            emit(f"sddmm/{name}/{tag}{cfg.astuple()}", t * 1e6,
                 f"nnz={csr.nnz};slots={p.num_slots};"
                 f"fill={p.slot_fill:.2f};model_us={model_us:.1f}")

        # GAT message step under the pair-optimal config, 1 and 4 heads
        gat_best, _ = cm.best(DIM, config_space(DIM), op="gat")
        p = build_pcsr(csr.indptr, csr.indices, csr.data,
                       csr.n_rows, csr.n_cols, gat_best)
        t = time_fn(_unfused_softmax_fn(p, Q, K), reps=3)
        emit(f"gat_softmax/{name}/engine", t * 1e6,
             f"cfg={gat_best.astuple()}")
        msg = make_gat_message_fn(p, backend="engine")
        t = time_fn(lambda: msg(Q, K, Vf), reps=3)
        emit(f"gat_message/{name}", t * 1e6,
             f"cfg={gat_best.astuple()};nnz={csr.nnz};"
             f"model_us={cm.time(DIM, gat_best, op='gat') * 1e6:.1f}")
        Qh = jnp.asarray(rng.standard_normal(
            (HEADS, csr.n_rows, DIM // HEADS)), jnp.float32)
        Kh = jnp.asarray(rng.standard_normal(
            (HEADS, csr.n_cols, DIM // HEADS)), jnp.float32)
        Vh = jnp.asarray(rng.standard_normal(
            (HEADS, csr.n_cols, DIM // HEADS)), jnp.float32)
        t = time_fn(lambda: msg(Qh, Kh, Vh), reps=3)
        emit(f"gat_message/{name}/h{HEADS}", t * 1e6,
             f"cfg={gat_best.astuple()}")

    # fused kernel vs unfused oracle at interpret-feasible scale
    small = rmat(10, 8, seed=0)
    cm = CostModel(small)
    gat_best, _ = cm.best(DIM, config_space(DIM), op="gat")
    p = build_pcsr(small.indptr, small.indices, small.data,
                   small.n_rows, small.n_cols, gat_best)
    Q = jnp.asarray(rng.standard_normal((small.n_rows, DIM)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((small.n_cols, DIM)), jnp.float32)
    t = time_fn(_unfused_softmax_fn(p, Q, K), reps=3)
    emit("gat_softmax/rmat10/engine", t * 1e6,
         f"cfg={gat_best.astuple()};nnz={small.nnz}")
    t = time_fn(lambda: sddmm_softmax(p, Q, K), reps=3)
    emit("gat_softmax/rmat10/fused_interpret", t * 1e6,
         f"cfg={gat_best.astuple()};nnz={small.nnz};"
         "note=one_kernel_softmax_stats_in_epilogue")
