"""Pallas-kernel roofline table: per-config cost-model terms for the
ParamSpMM TPU kernel on representative graphs (the kernel's §Roofline
contribution — the LM-cell roofline lives in launch/dryrun)."""
from __future__ import annotations

from repro.core.cost_model import CostModel
from repro.core.pcsr import config_space
from .common import bench_corpus, emit

DIM = 128
GRAPHS = ["sbm32x256", "rmat13", "er16000", "grid128"]


def run():
    gs = {g.name: g for g in bench_corpus()}
    for name in GRAPHS:
        if name not in gs:
            continue
        cm = CostModel(gs[name].csr)
        best, _ = cm.best(DIM, config_space(DIM))
        cb = cm.cost(DIM, best)
        bound = "mem" if cb.t_mem > max(cb.t_compute, cb.t_overhead) else \
            ("compute" if cb.t_compute > cb.t_overhead else "issue")
        emit(f"kernel/{name}/best", cb.total * 1e6,
             f"cfg={best.astuple()};t_mem={cb.t_mem*1e6:.1f}us;"
             f"t_comp={cb.t_compute*1e6:.1f}us;"
             f"t_ovh={cb.t_overhead*1e6:.1f}us;bound={bound};"
             f"steps={cb.steps}")
