"""Paper Fig. 5 / §6.5: end-to-end GNN training — GCN and GIN with
ParamSpMM vs the vendor-library aggregation (DGL analog = BCOO backend),
per-step wall-clock and speedups, hidden sizes {32, 64, 128}."""
from __future__ import annotations

from repro.apps.gnn import train_gnn
from repro.data.tasks import community_task
from .common import emit

HIDDENS = (32, 64, 128)


def run():
    task = community_task(n_blocks=12, block_size=256, p_in=0.15,
                          noise=1.2, seed=3)
    for model in ("gcn", "gin"):
        for h in HIDDENS:
            base = train_gnn(task, model=model, hidden=h, n_layers=5,
                             steps=12, spmm_mode="cusparse")
            # epilogue-fused path (the default; GCN hands bias/ReLU to
            # the SpMM epilogue) and — for GCN only, the one model whose
            # layers consult the fusion surface — the classic association
            ours = train_gnn(task, model=model, hidden=h, n_layers=5,
                             steps=12, spmm_mode="paramspmm",
                             spmm_kwargs={"reorder": True,
                                          "select": "measured"})
            unfused = ""
            if model == "gcn":
                unf = train_gnn(task, model=model, hidden=h, n_layers=5,
                                steps=12, spmm_mode="paramspmm",
                                fused=False,
                                spmm_kwargs={"reorder": True,
                                             "select": "measured"})
                unfused = f"unfused_us={unf.seconds_per_step * 1e6:.1f};"
            sp = base.seconds_per_step / ours.seconds_per_step
            emit(f"fig5/{model}/h{h}", ours.seconds_per_step * 1e6,
                 f"speedup_vs_dgl_analog={sp:.2f}x;{unfused}"
                 f"acc={ours.val_acc:.3f};base_acc={base.val_acc:.3f};"
                 f"cfg={ours.config.astuple() if ours.config else None}")
