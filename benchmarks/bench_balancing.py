"""Paper Fig. 1: SpMM throughput with/without workload balancing across
graphs of varying degree distribution (CV) — balancing helps skewed
(power-law) graphs, hurts balanced ones."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import engine_spmm
from repro.core.autotune import time_fn
from repro.core.features import extract_features
from repro.core.pcsr import SpMMConfig, build_pcsr
from .common import bench_corpus, emit, gflops, subset

DIM = 32


def run():
    gs = subset(bench_corpus(), k=12)
    rng = np.random.default_rng(0)
    for g in gs:
        from repro.core.cost_model import CostModel
        cm = CostModel(g.csr)
        B = jnp.asarray(rng.standard_normal((g.csr.n_cols, DIM)),
                        jnp.float32)
        cv = extract_features(g.csr).as_dict()["cv"]
        res = {}
        for S in (False, True):
            cfg = SpMMConfig(V=1, S=S, F=1, W=16)
            p = build_pcsr(g.csr.indptr, g.csr.indices, g.csr.data,
                           g.csr.n_rows, g.csr.n_cols, cfg)
            t_model = cm.time(DIM, cfg)
            t_cpu = time_fn(engine_spmm, p, B, reps=3)
            res[S] = t_model
            emit(f"fig1/{g.name}/S{int(S)}", t_model * 1e6,
                 f"tpu_gflops={gflops(g.csr, DIM, t_model):.2f};"
                 f"cv={cv:.2f};sr={p.split_ratio:.2f};"
                 f"cpu_us={t_cpu*1e6:.0f}")
        winner = "balanced" if res[True] < res[False] else "unbalanced"
        emit(f"fig1/{g.name}/winner", 0.0, f"winner={winner};cv={cv:.2f}")
