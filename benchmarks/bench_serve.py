"""Serving latency/throughput under seeded load replay (serving tier).

Drives the ``repro.serve`` request path — sample → extract → bucket pack
(cached steering) → fused forward — with the same seeded bursty
synthetic stream the soak test replays, and reports:

  serve/<graph>/<model>/p50      p50 request latency (µs)
  serve/<graph>/<model>/p99      p99 request latency (µs)
  serve/<graph>/<model>/request  mean service time per request (µs), with
                                 throughput (requests/s), steering-pack
                                 cache hit rate, and compiled-bucket count
                                 in the derived field

plus a structured dict (``run.py --json`` folds it into BENCH_spmm.json
as the ``serve`` section).  Latency percentiles include queueing inside
a tick window (requests waiting for their batch), so p99 ≫ p50 is the
batching tradeoff, not noise.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit


def _one(graph_name, csr, *, model, backend, n_requests, seed,
         tick_every, feat=16, hidden=32, classes=8):
    import jax

    from repro.models.gnn import init_gat, init_gcn
    from repro.serve import GNNService, replay, synthetic_stream

    g = csr if model == "gat" else csr.gcn_normalize()
    rng = np.random.default_rng(seed)
    feats = rng.integers(0, 4, (g.n_rows, feat)).astype(np.float32)
    init = init_gat if model == "gat" else init_gcn
    params = init(jax.random.PRNGKey(seed), [feat, hidden, classes])

    stream = synthetic_stream(n_requests, g.n_rows, seed=seed)
    svc = GNNService(g, feats, params, model=model, backend=backend)
    t0 = time.perf_counter()
    results = replay(svc, stream, tick_every=tick_every)
    wall = time.perf_counter() - t0

    lat_us = np.array([r.latency_s for r in results]) * 1e6
    p50, p99 = float(np.percentile(lat_us, 50)), float(np.percentile(lat_us, 99))
    rps = len(results) / wall
    cache = svc.cache
    base = f"serve/{graph_name}/{model}"
    tag = (f"model={model};backend={backend};requests={len(results)};"
           f"batches={len(svc.batch_log)};tick_every={tick_every}")
    emit(f"{base}/p50", p50, tag)
    emit(f"{base}/p99", p99, tag)
    emit(f"{base}/request", wall * 1e6 / len(results),
         f"{tag};throughput_rps={rps:.1f};"
         f"hit_rate={cache.hit_rate:.3f};hits={cache.hits};"
         f"misses={cache.misses};compiled_buckets={svc.compiled_buckets}")
    return {
        "graph": graph_name, "model": model, "backend": backend,
        "requests": len(results), "batches": len(svc.batch_log),
        "tick_every": tick_every,
        "latency_us_p50": p50, "latency_us_p99": p99,
        "throughput_rps": rps,
        "cache_hits": cache.hits, "cache_misses": cache.misses,
        "cache_hit_rate": cache.hit_rate,
        "compiled_buckets": svc.compiled_buckets,
    }


def run(n_requests: int = 48, seed: int = 0, tick_every: int = 8):
    """Latency/throughput sweep on the serve corpus (engine backend —
    interpret-mode Pallas wall-clock would measure the interpreter, not
    the serving tier)."""
    from repro.data.graphs import corpus

    specs = {s.name: s for s in corpus("serve")}
    runs = []
    for graph_name, model in (("rmat13", "gcn"), ("ba10k", "gcn"),
                              ("ba10k", "gat")):
        runs.append(_one(graph_name, specs[graph_name].csr, model=model,
                         backend="engine", n_requests=n_requests,
                         seed=seed, tick_every=tick_every))
    return {"runs": runs,
            "stream": {"requests": n_requests, "seed": seed,
                       "tick_every": tick_every}}


if __name__ == "__main__":
    run()
