"""Shared benchmark infrastructure: cached corpus/features, timing, CSV."""
from __future__ import annotations

import functools
import sys
import time

import jax
import numpy as np

from repro.core.autotune import throughput_gflops, time_fn
from repro.core.features import extract_features
from repro.data.graphs import corpus

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@functools.lru_cache(maxsize=4)
def bench_corpus(scale: str = "bench"):
    return corpus(scale)


def subset(graphs, max_nnz=300_000, k=12):
    """Deterministic measurement subset (CPU wall-clock budget)."""
    ok = [g for g in graphs if g.csr.nnz <= max_nnz]
    # spread across families
    fams: dict = {}
    for g in ok:
        fams.setdefault(g.family, []).append(g)
    out, i = [], 0
    while len(out) < min(k, len(ok)):
        for f in sorted(fams):
            if i < len(fams[f]) and len(out) < k:
                out.append(fams[f][i])
        i += 1
    return out


def gflops(csr, dim, seconds):
    return throughput_gflops(csr, dim, seconds)


def count_pallas_calls(fn):
    """Run ``fn`` with the Pallas dispatch intercepted; return the kernel
    names in launch order (trace-time count == launch count per call).
    The ONE shared counter — `tests/test_fusion.py` asserts on it and
    `bench_fusion` records it into BENCH_spmm.json, so the two can never
    disagree about what counts as a kernel launch."""
    from jax.experimental import pallas as pl
    calls = []
    orig = pl.pallas_call

    def counting(*a, **kw):
        calls.append(kw.get("name", "?"))
        return orig(*a, **kw)

    pl.pallas_call = counting
    try:
        jax.block_until_ready(fn())
    finally:
        pl.pallas_call = orig
    return calls
