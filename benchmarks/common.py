"""Shared benchmark infrastructure: cached corpus/features, timing, CSV."""
from __future__ import annotations

import functools
import sys
import time

import jax
import numpy as np

from repro.core.autotune import throughput_gflops, time_fn
from repro.core.features import extract_features
from repro.data.graphs import corpus

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float | None, derived: str = ""):
    """``us_per_call=None`` marks a row with no timing (a skipped
    measurement — the derived field must say why via ``skipped=...``)."""
    ROWS.append((name, us_per_call, derived))
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    print(f"{name},{us},{derived}", flush=True)


# ------------------------------------------------------- row schema
# The golden schema every emitted row must satisfy — ``run.py --json``
# validates before writing BENCH_spmm.json and tests/test_bench_schema.py
# re-asserts it on the generated artifact, so bench emitters cannot drift.

def parse_derived(derived: str) -> dict:
    """Parse a row's ``derived`` field: ``;``-separated ``k=v`` entries
    (empty string → ``{}``).  Raises ``ValueError`` on any entry that is
    not of that shape — the contract that keeps BENCH_spmm.json
    machine-readable across benchmark modules."""
    out: dict = {}
    if not derived:
        return out
    for entry in derived.split(";"):
        if not entry:
            continue
        key, eq, val = entry.partition("=")
        if not eq or not key:
            raise ValueError(
                f"derived entry {entry!r} is not k=v (in {derived!r})")
        out[key] = val
    return out


def validate_row(row: dict) -> dict:
    """Assert one JSON row carries exactly ``name``/``us_per_call``/
    ``derived`` with a non-empty name, a finite non-negative time, and a
    parseable derived field; returns ``parse_derived(row['derived'])``.

    Skipped rows (``skipped=...`` in derived) must carry
    ``us_per_call=None`` — and only they may: a timing next to a skip
    annotation reads as a measurement of the skipped thing downstream."""
    if set(row) != {"name", "us_per_call", "derived"}:
        raise ValueError(f"row keys {sorted(row)} != "
                         f"['derived', 'name', 'us_per_call']")
    if not isinstance(row["name"], str) or not row["name"]:
        raise ValueError(f"row name {row['name']!r} must be a non-empty str")
    if not isinstance(row["derived"], str):
        raise ValueError(f"{row['name']}: derived must be a str")
    try:
        parsed = parse_derived(row["derived"])
    except ValueError as e:
        raise ValueError(f"{row['name']}: {e}") from None
    us = row["us_per_call"]
    if "skipped" in parsed:
        if us is not None:
            raise ValueError(
                f"{row['name']}: skipped row must carry us_per_call=None, "
                f"not {us!r} (skipped={parsed['skipped']})")
    elif us is None:
        raise ValueError(f"{row['name']}: us_per_call=None is only legal "
                         "on skipped rows (derived skipped=...)")
    elif not isinstance(us, (int, float)) or isinstance(us, bool) \
            or not np.isfinite(us) or us < 0:
        raise ValueError(f"{row['name']}: us_per_call {us!r} must be a "
                         "finite non-negative number")
    return parsed


@functools.lru_cache(maxsize=4)
def bench_corpus(scale: str = "bench"):
    return corpus(scale)


def subset(graphs, max_nnz=300_000, k=12):
    """Deterministic measurement subset (CPU wall-clock budget)."""
    ok = [g for g in graphs if g.csr.nnz <= max_nnz]
    # spread across families
    fams: dict = {}
    for g in ok:
        fams.setdefault(g.family, []).append(g)
    out, i = [], 0
    while len(out) < min(k, len(ok)):
        for f in sorted(fams):
            if i < len(fams[f]) and len(out) < k:
                out.append(fams[f][i])
        i += 1
    return out


def gflops(csr, dim, seconds):
    return throughput_gflops(csr, dim, seconds)


def count_pallas_calls(fn):
    """Run ``fn`` with the Pallas dispatch intercepted; return the kernel
    names in launch order (trace-time count == launch count per call).
    The ONE shared counter — `tests/test_fusion.py` asserts on it,
    `bench_fusion` records it into BENCH_spmm.json, and the obs layer's
    tracing probe feeds ``pallas_calls_total`` through the same
    ``repro.obs.metrics.intercept_pallas`` hook, so none of the three
    can disagree about what counts as a kernel launch."""
    from repro.obs.metrics import intercept_pallas

    calls: list[str] = []
    with intercept_pallas(calls.append):
        jax.block_until_ready(fn())
    return calls
