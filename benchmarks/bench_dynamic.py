"""Dynamic-graph benchmark: the bounded-staleness story, measured.

For each graph this drives a randomized insert/delete churn stream
through the dynamic layer (`repro.dynamic`) and reports:

* **mutation throughput** — host edges/second absorbed by
  ``DynamicPCSR`` (slack-slot vs delta-chunk split in the derived
  field);
* **degraded-vs-fresh gap**, priced AND measured — the engine SpMM
  wall-clock on the churned steering arrays vs after ``repack()``,
  next to ``degraded_kernel_cost`` / ``kernel_cost`` pricing of the
  same two grids (the governor's decision inputs, so the artifact
  shows whether the priced gap tracks the measured one);
* **governor trigger points** — a second, governed stream
  (``auto_heal=True``) recording at which step the first ``repack``
  fired and the full action tally;
* **decider agreement** pre/post re-pack — whether the config in use
  is the one ``CostModel.best`` would pick for the *current* edge set.
  Fresh graph: 1 by construction.  After churn the stale pick may
  disagree; after the re-pack (which re-runs the pick) agreement must
  return to the fresh-graph baseline of 1 — the acceptance number.

Structured metrics feed the ``"dynamic"`` section of
``BENCH_spmm.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.autotune import time_fn
from repro.core.cost_model import (CostModel, degraded_kernel_cost,
                                   pack_setup_seconds)
from repro.core.engine import make_spmm_fn
from repro.core.pcsr import config_space
from repro.dynamic import DynamicGraph

from .common import bench_corpus, emit

DIM = 32
GRAPHS = ("rmat10", "ba1k")     # one skewed, one power-law — small tier
BATCHES = 6
INSERTS = 150
DELETES = 130
REPS = 5


def _churn(rng, dyn, n: int, n_ins: int, n_del: int):
    """One random churn batch: ``n_ins`` inserts + ``n_del`` deletes of
    existing edges (the mix that actually degrades the layout — pure
    inserts are mostly absorbed by slack)."""
    r = rng.integers(0, n, n_ins)
    c = rng.integers(0, n, n_ins)
    v = rng.uniform(0.5, 1.5, n_ins).astype(np.float32)
    csr = dyn.to_csr()
    rows = np.repeat(np.arange(csr.n_rows), np.diff(csr.indptr))
    pick = rng.permutation(csr.nnz)[:n_del]
    return (r, c, v), (rows[pick], csr.indices[pick])


def _agreement(dyn, space) -> int:
    """1 iff the config in use is ``CostModel.best`` for the live edges."""
    best, _ = CostModel(dyn.to_csr()).best(DIM, space)
    return int(best == dyn.config)


def _measure_spmm(pcsr, B) -> float:
    fn = make_spmm_fn(pcsr, backend="engine")
    return time_fn(lambda: fn(B), reps=REPS, warmup=1)


def _priced_degraded(dyn) -> float:
    return degraded_kernel_cost(DIM, dyn.config, C=dyn.num_chunks,
                                K=dyn.K,
                                n_blocks_visited=dyn.n_visited_blocks).total


def run():
    """Churn stream per graph: throughput, degraded/fresh gap, governor
    trigger points, pre/post-repack agreement."""
    import jax.numpy as jnp

    metrics: dict = {"dim": DIM, "batches": BATCHES,
                     "inserts_per_batch": INSERTS,
                     "deletes_per_batch": DELETES, "graphs": {}}
    space = config_space(DIM)
    for spec in bench_corpus("small"):
        if spec.name not in GRAPHS:
            continue
        csr = spec.csr
        rng = np.random.default_rng(7)
        B = jnp.asarray(rng.standard_normal((csr.n_cols, DIM)),
                        jnp.float32)

        # ---- ungoverned stream: let the layout degrade, then repack
        g = DynamicGraph(csr, DIM, auto_heal=False)
        dyn = g.dyn
        agree_fresh = _agreement(dyn, space)
        edges = 0
        t0 = time.perf_counter()
        for _ in range(BATCHES):
            (r, c, v), (dr, dc) = _churn(rng, dyn, csr.n_rows,
                                         INSERTS, DELETES)
            dyn.insert_edges(r, c, v)
            dyn.delete_edges(dr, dc)
            edges += len(r) + len(dr)
        mutate_s = time.perf_counter() - t0
        emit(f"dynamic/{spec.name}/mutate", mutate_s / BATCHES * 1e6,
             f"family={spec.family};edges_per_s={edges / mutate_s:.0f};"
             f"batches={BATCHES};"
             f"slack_inserts={dyn.n_slack_inserts};"
             f"delta_chunks={dyn.n_delta_chunks};"
             f"tombstones={dyn.n_tombstones}")

        agree_deg = _agreement(dyn, space)
        deg_meas = _measure_spmm(dyn.pcsr, B)
        deg_priced = _priced_degraded(dyn)
        chunks_deg, fill_deg = dyn.num_chunks, dyn.slot_fill
        slack_i, delta_c = dyn.n_slack_inserts, dyn.n_delta_chunks
        emit(f"dynamic/{spec.name}/degraded", deg_meas * 1e6,
             f"priced_us={deg_priced * 1e6:.1f};chunks={chunks_deg};"
             f"slot_fill={fill_deg:.3f};agreement={agree_deg}")

        t0 = time.perf_counter()
        cfg = g.repack()                 # fresh config pick on live edges
        repack_s = time.perf_counter() - t0
        agree_post = _agreement(dyn, space)
        fresh_meas = _measure_spmm(dyn.pcsr, B)
        fresh_priced = CostModel(dyn.to_csr()).cost(DIM, cfg).total
        emit(f"dynamic/{spec.name}/repack", repack_s * 1e6,
             f"cfg={cfg.astuple()};measured_fresh_us={fresh_meas * 1e6:.1f};"
             f"priced_fresh_us={fresh_priced * 1e6:.1f};"
             f"chunks={dyn.num_chunks};"
             f"measured_gain={deg_meas / max(fresh_meas, 1e-12):.3f};"
             f"priced_gain={deg_priced / max(fresh_priced, 1e-12):.3f};"
             f"priced_setup_us={pack_setup_seconds(dyn.nnz) * 1e6:.1f};"
             f"agreement={agree_post}")

        # ---- governed stream: where does the governor pull the trigger?
        rng2 = np.random.default_rng(7)
        gg = DynamicGraph(csr, DIM, auto_heal=True, slack=1.05,
                          amortize_steps=10)
        actions: list[str] = []
        t0 = time.perf_counter()
        for _ in range(BATCHES):
            (r, c, v), (dr, dc) = _churn(rng2, gg.dyn, csr.n_rows,
                                         INSERTS, DELETES)
            gg.insert_edges(r, c, v)
            _, dec = gg.delete_edges(dr, dc)
            actions.append(dec.action)
        gov_s = time.perf_counter() - t0
        first = next((i for i, a in enumerate(actions) if a == "repack"),
                     None)
        tally = {a: actions.count(a) for a in ("none", "reselect", "repack")}
        emit(f"dynamic/{spec.name}/governor",
             gov_s / (2 * BATCHES) * 1e6,
             f"first_repack_step={first};"
             f"none={tally['none']};reselect={tally['reselect']};"
             f"repack={tally['repack']};"
             f"post_agreement={_agreement(gg.dyn, space)}")

        metrics["graphs"][spec.name] = {
            "family": spec.family,
            "nnz": int(csr.nnz),
            "edges_per_s": edges / mutate_s,
            "slack_inserts": int(slack_i),
            "delta_chunks": int(delta_c),
            "degraded_chunks": int(chunks_deg),
            "degraded_slot_fill": float(fill_deg),
            "measured_degraded_us": deg_meas * 1e6,
            "measured_fresh_us": fresh_meas * 1e6,
            "priced_degraded_us": deg_priced * 1e6,
            "priced_fresh_us": fresh_priced * 1e6,
            "repack_host_us": repack_s * 1e6,
            "governor_actions": actions,
            "governor_first_repack_step": first,
            "agreement_fresh": agree_fresh,
            "agreement_degraded": agree_deg,
            "agreement_post_repack": agree_post,
        }
    return metrics
