"""Paper Table 2: distribution of the optimal coarsening factor F and the
MAC-job gap.  TPU adaptation: ω = 128 lanes (not 32 threads), so F matters
for dim > 128; gap_F = lane-padding when dim mod F·128 ≠ 0.  Optimal F per
graph from the TPU cost model (F's per-step overhead isn't visible to CPU
wall-clock — DESIGN.md §7)."""
from __future__ import annotations

from collections import Counter

from repro.core.cost_model import CostModel
from repro.core.pcsr import config_space
from .common import bench_corpus, emit

DIMS = (128, 160, 256, 384)
OMEGA = 128


def gap(dim, F):
    tn = min(dim, F * OMEGA)
    tr = dim % (F * OMEGA)
    return tn - tr if tr else 0


def run():
    gs = bench_corpus()
    cms = {g.name: CostModel(g.csr) for g in gs}
    for dim in DIMS:
        space = config_space(dim, max_f=4)
        fs = sorted({c.F for c in space})
        counts = Counter()
        for g in gs:
            best, _ = cms[g.name].best(dim, space)
            counts[best.F] += 1
        for F in fs:
            emit(f"table2/dim{dim}/F{F}", 0.0,
                 f"pct={100.0*counts[F]/len(gs):.1f};gap={gap(dim, F)}")
