"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  table1 — blocking (V) vs data locality          [paper Table 1]
  fig1   — workload balancing (S) vs CV           [paper Fig. 1]
  table2 — optimal-F distribution + MAC gap       [paper Table 2]
  table5 — decider accuracy                       [paper Table 5]
  table4 — speedups vs baseline families          [paper Table 4/Fig. 4]
  table6 — reordering ablation                    [paper Table 6]
  fig5   — GCN/GIN end-to-end training            [paper Fig. 5]
  kernel — Pallas-kernel roofline terms           [§Roofline]
  sddmm  — SDDMM + fused GAT message timings      [attention extension]
  dist   — partitioned SpMM/GAT scaling, per-     [distributed extension]
           shard adaptive-config table, halo/
           compute overlap on/off column
  fusion — kernel/elementwise-pass counts +       [fusion extension]
           fused-vs-unfused pricing
  spmm   — balanced-vs-uniform chunk schedule     [B-mode extension]
           priced + measured makespan on the
           skewed corpus
  calibration — priced-vs-measured Spearman ρ     [calibration extension]
           per corpus tier, pre/post NNLS fit of
           the cost-model constants
  decider — decider retrained on calibrated       [observability extension]
           labels: decider-vs-oracle agreement
           + regret on held-out graphs
  dynamic — mutation-stream throughput, de-       [dynamic-graph extension]
           graded-vs-fresh priced + measured
           gap, governor trigger points, pre/
           post-repack decider agreement
  serve  — request-serving p50/p99 latency +      [serving tier]
           throughput under seeded load replay,
           steering-pack cache hit rate

``--json [PATH]`` additionally writes the machine-readable
``BENCH_spmm.json`` (default path): every emitted CSV row plus the
fusion/dist/spmm/calibration/decider/dynamic/serve sections' structured
metrics
(kernel counts, elementwise-pass counts, per-config fused/unfused
times, per-shard configs, overlap on/off timings, fitted coefficients
and rank correlations, decider agreement/regret) — the perf-trajectory
artifact CI archives from PR 4 on (dist folded in from PR 5,
calibration from PR 7, decider from PR 8).  Every row is checked
against the golden schema (``common.validate_row``) before the file is
written.

``--trace [PATH]`` runs the whole sweep under ``repro.obs`` tracing:
one span per benchmark job, the full pack/decision instrumentation
underneath, exported as Chrome-trace JSON; with ``--json`` the trace
path is recorded in the payload next to the rows.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    ap.add_argument("--json", nargs="?", const="BENCH_spmm.json",
                    default=None, metavar="PATH",
                    help="write BENCH_spmm.json (rows + fusion metrics)")
    ap.add_argument("--trace", nargs="?", const="BENCH_trace.json",
                    default=None, metavar="PATH",
                    help="write a repro.obs Chrome-trace JSON of the "
                    "sweep (read with repro.apps.obs_report / Perfetto)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_balancing, bench_blocking,
                            bench_calibration, bench_coarsening,
                            bench_decider, bench_dist, bench_dynamic,
                            bench_fusion, bench_gnn_train, bench_kernel,
                            bench_reorder, bench_sddmm, bench_serve,
                            bench_speedups, bench_spmm)
    from benchmarks.common import ROWS, emit, validate_row

    print("name,us_per_call,derived")
    jobs = {
        "table1": bench_blocking.run,
        "fig1": bench_balancing.run,
        "table2": bench_coarsening.run,
        "table5": bench_decider.run,     # also trains + saves the decider
        "table4": None,                  # needs the trained decider
        "table6": bench_reorder.run,
        "fig5": bench_gnn_train.run,
        "kernel": bench_kernel.run,
        "sddmm": bench_sddmm.run,
        "dist": bench_dist.run,
        "fusion": bench_fusion.run,      # returns structured metrics
        "spmm": bench_spmm.run,          # returns structured metrics
        "calibration": bench_calibration.run,  # returns structured metrics
        "decider": bench_decider.run_calibrated,  # returns structured
        "dynamic": bench_dynamic.run,    # returns structured metrics
        "serve": bench_serve.run,        # returns structured metrics
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    decider = None
    extras = {}
    from repro.obs import span, tracing
    ctx = tracing(args.trace) if args.trace else contextlib.nullcontext()
    with ctx:
        for key, fn in jobs.items():
            if key not in only:
                continue
            t0 = time.time()
            with span(f"bench.{key}"):
                if key == "table5":
                    decider = fn()
                elif key == "table4":
                    bench_speedups.run(decider)
                elif key in ("fusion", "dist", "spmm", "calibration",
                             "decider", "dynamic", "serve"):  # → JSON
                    extras[key] = fn()
                else:
                    fn()
            emit(f"{key}/__elapsed", (time.time() - t0) * 1e6, "")
    if args.trace:
        print(f"# wrote {args.trace}", flush=True)

    if args.json:
        rows = [{"name": n, "us_per_call": us, "derived": d}
                for n, us, d in ROWS]
        for row in rows:                 # golden schema — fail loud, not
            validate_row(row)            # after the artifact is archived
        payload = {"rows": rows, **extras}
        if args.trace:
            payload["trace"] = args.trace   # the run's telemetry artifact
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
