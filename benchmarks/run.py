"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  table1 — blocking (V) vs data locality          [paper Table 1]
  fig1   — workload balancing (S) vs CV           [paper Fig. 1]
  table2 — optimal-F distribution + MAC gap       [paper Table 2]
  table5 — decider accuracy                       [paper Table 5]
  table4 — speedups vs baseline families          [paper Table 4/Fig. 4]
  table6 — reordering ablation                    [paper Table 6]
  fig5   — GCN/GIN end-to-end training            [paper Fig. 5]
  kernel — Pallas-kernel roofline terms           [§Roofline]
  sddmm  — SDDMM + fused GAT message timings      [attention extension]
  dist   — partitioned SpMM scaling + per-shard   [distributed extension]
           adaptive-config table
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    args = ap.parse_args(argv)

    from benchmarks import (bench_balancing, bench_blocking,
                            bench_coarsening, bench_decider, bench_dist,
                            bench_gnn_train, bench_kernel, bench_reorder,
                            bench_sddmm, bench_speedups)
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    jobs = {
        "table1": bench_blocking.run,
        "fig1": bench_balancing.run,
        "table2": bench_coarsening.run,
        "table5": bench_decider.run,     # also trains + saves the decider
        "table4": None,                  # needs the trained decider
        "table6": bench_reorder.run,
        "fig5": bench_gnn_train.run,
        "kernel": bench_kernel.run,
        "sddmm": bench_sddmm.run,
        "dist": bench_dist.run,
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    decider = None
    for key, fn in jobs.items():
        if key not in only:
            continue
        t0 = time.time()
        if key == "table5":
            decider = fn()
        elif key == "table4":
            bench_speedups.run(decider)
        else:
            fn()
        emit(f"{key}/__elapsed", (time.time() - t0) * 1e6, "")


if __name__ == "__main__":
    main()
