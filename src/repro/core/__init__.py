"""Core ParamSpMM machinery: the PCSR data structure, configuration
search (cost model / features / decider), and the sparse containers.

The heavily-used names are re-exported here so downstream code imports
``repro.core`` instead of deep-importing submodules.  Only numpy-level
modules are pulled in eagerly — the JAX-importing layers (``engine``,
``autotune``) stay behind explicit submodule imports to keep
``import repro.core`` light.
"""
from .calibrate import (CalibrationResult, CalibrationSample, fit,
                        fit_columns, spearman)
from .cost_model import (CostBreakdown, CostModel, degraded_kernel_cost,
                         kernel_cost, pack_setup_seconds, sddmm_cost,
                         unfused_bytes, unfused_penalty)
from .features import FEATURE_NAMES, MatrixFeatures, extract_features
from .pcsr import (PCSR, PCSRStats, SpMMConfig, balanced_capacity,
                   build_pcsr, config_space, pcsr_stats, pcsr_to_coo,
                   slot_transfer_map, transpose_csr, transpose_pcsr)
from .sparse import CSRMatrix

__all__ = [
    "CSRMatrix",
    "PCSR", "PCSRStats", "SpMMConfig", "balanced_capacity", "build_pcsr",
    "config_space", "pcsr_stats", "pcsr_to_coo", "slot_transfer_map",
    "transpose_csr", "transpose_pcsr",
    "CostBreakdown", "CostModel", "degraded_kernel_cost", "kernel_cost",
    "pack_setup_seconds", "sddmm_cost",
    "unfused_bytes", "unfused_penalty",
    "CalibrationResult", "CalibrationSample", "fit", "fit_columns",
    "spearman",
    "FEATURE_NAMES", "MatrixFeatures", "extract_features",
]
