"""SpMM baselines the paper compares against (§6.1), as faithful analogues
on the JAX/TPU side (torch/CUDA originals don't exist here — DESIGN.md §7):

  * cuSPARSE  → vendor sparse library path = ``jax.experimental.sparse``
    BCOO matmul (the library-provided, input-agnostic kernel).
  * GE-SpMM   → static CSR row-wise kernel: gather + segment-sum
    (coarsening fixed by the compiler, no blocking/balancing).
  * GNNAdvisor → heuristic runtime: always-on balancing, no blocking,
    dim-scaled coarsening (their §related-work behaviour the paper calls
    out: "simply increase F with dim").
  * DA-SpMM   → ML-adaptive but over a reduced space (no blocking, no
    coarsening — the paper notes their space overlooks V and F).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .engine import engine_spmm
from .pcsr import SpMMConfig, build_pcsr, LANES
from .sparse import CSRMatrix
from repro.kernels.paramspmm.ref import spmm_ref


# ---------------------------------------------------------------- cuSPARSE
def make_cusparse_analog(csr: CSRMatrix):
    from jax.experimental import sparse as jsparse
    rows = np.repeat(np.arange(csr.n_rows), csr.degrees)
    bcoo = jsparse.BCOO((jnp.asarray(csr.data),
                         jnp.asarray(np.stack([rows, csr.indices], 1))),
                        shape=csr.shape)

    @jax.jit
    def fn(B):
        return bcoo @ B
    return fn


# ----------------------------------------------------------------- GE-SpMM
def make_gespmm_analog(csr: CSRMatrix):
    indptr = np.asarray(csr.indptr)
    indices = jnp.asarray(csr.indices, jnp.int32)
    data = jnp.asarray(csr.data)
    n = csr.n_rows

    @jax.jit
    def fn(B):
        return spmm_ref(indptr, indices, data, B, n)
    return fn


# -------------------------------------------------------------- GNNAdvisor
def gnnadvisor_config(dim: int) -> SpMMConfig:
    f = max(1, -(-dim // LANES))           # F grows with dim, gap ignored
    return SpMMConfig(V=1, S=True, F=min(f, 4), W=8)


def make_gnnadvisor_analog(csr: CSRMatrix, dim: int):
    cfg = gnnadvisor_config(dim)
    pcsr = build_pcsr(csr.indptr, csr.indices, csr.data,
                      csr.n_rows, csr.n_cols, cfg)
    return functools.partial(engine_spmm, pcsr), cfg


# ---------------------------------------------------------------- DA-SpMM
def daspmm_space(dim: int):
    """DA-SpMM's adaptivity without blocking (V) or coarsening (F)."""
    return [SpMMConfig(V=1, S=s, F=1, W=r) for s in (False, True)
            for r in (8, 16, 32)]
