"""Analytical TPU-v5e cost model for the ParamSpMM kernel.

This is the napkin-math layer the perf loop reasons with (DESIGN.md §6) and
the label source for decider training at corpus scale.  It prices the exact
grid the kernel would execute — per (V,W) block populations come from
``pcsr_stats`` so every padding effect the paper discusses is priced, not
approximated:

  * V padding (PR_V)      → more slots when vectors are half-empty;
  * S chunk padding       → slots = Σ_b ceil(cnt_b/K)·K;
  * B balanced schedule   → distribution-derived K cuts padding slots on
                            skewed graphs, priced against the extra
                            per-chunk ``CHUNK_SETUP`` the finer split pays;
  * F MAC-job gap         → J·Dblk ≥ dim lane waste;
  * W scatter granularity → output-block traffic ∝ blocks touched.

Hardware constants (TPU v5e, from the assignment + public specs):
  197 TFLOP/s bf16 MXU — NOT the unit here: SpMM MACs run on the VPU;
  we assume 8 sublanes × 128 lanes × 2 FMA × 0.94 GHz ≈ 1.9 TFLOP/s f32.
  HBM 819 GB/s; per-step DMA issue overhead ~100 ns (double-buffered).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.obs import decisions as _obs_decisions, trace as _obs_trace

from .pcsr import SpMMConfig, PCSRStats, pcsr_stats, LANES
from .sparse import CSRMatrix

HBM_BW = 819e9            # B/s
VPU_FLOPS = 1.9e12        # f32 FMA/s (VPU, not MXU)
STEP_OVERHEAD = 100e-9    # s per grid step not hidden by double buffering
# Per-chunk setup not hidden by double buffering: the scalar-prefetched
# steering fetch + the chunk's vals-block DMA issue.  This is the term
# that stops the balanced schedule (B=True) from splitting ever finer —
# fewer padding slots trade against more chunks, the same λ trade
# ``balanced_capacity`` optimizes (BALANCE_LAMBDA ≈ CHUNK_SETUP in units
# of per-slot step overhead).
CHUNK_SETUP = 400e-9
DTYPE_BYTES = 4

# Re-pack amortization (the dynamic-graph governor's trade): a full PCSR
# re-pack is host-side vectorized numpy — a fixed launch/allocation cost
# plus a per-nonzero sort/unique throughput term.  The governor charges
# ``pack_setup_seconds(nnz) / amortize_steps`` against the per-step
# savings of a fresh layout, so a re-pack only fires when the degraded
# steering arrays are slow enough to pay it back within the amortization
# horizon.
PACK_SETUP = 200e-6        # s fixed per pack (alloc + launch + finalize)
PACK_SETUP_PER_NNZ = 4e-9  # s per nonzero (sort/unique/bincount passes)


def pack_setup_seconds(nnz: int) -> float:
    """Priced host time of one full ``build_pcsr`` re-pack."""
    return PACK_SETUP + PACK_SETUP_PER_NNZ * max(0, int(nnz))


@dataclass
class CostBreakdown:
    t_mem: float
    t_compute: float
    t_overhead: float
    bytes_gather: float
    bytes_meta: float
    bytes_out: float
    flops: float
    steps: int
    # per-chunk setup events the grid pays (J·C for the SpMM's dim-tile
    # revisits, C for the SDDMM) — kept separate from ``t_overhead`` so
    # the calibration fit (``repro.core.calibrate``) can treat "number of
    # chunk setups" as its own feature column with a learned coefficient
    # instead of baking ``CHUNK_SETUP`` in.
    chunk_setups: int = 0

    @property
    def total(self) -> float:
        return max(self.t_mem, self.t_compute) + self.t_overhead

    @property
    def bytes_total(self) -> float:
        return self.bytes_gather + self.bytes_meta + self.bytes_out


def _head_dim(dim: int, heads: int) -> int:
    """Per-head feature width: multi-head layers split ``dim`` across
    heads (``gat_forward``), so head tiling runs H grids of d/H lanes."""
    return max(1, -(-dim // heads))


def kernel_cost(stats: PCSRStats, dim: int, config: SpMMConfig,
                dtype_bytes: int = DTYPE_BYTES, *, heads: int = 1,
                epilogue: bool = False,
                residual: bool = False) -> CostBreakdown:
    """Price one SpMM under ⟨W,F,V,S⟩ given (V,W)-matched block stats.

    ``heads > 1`` prices the head-tiled grid (``PCSR.steering(H)``): H× the
    chunks and output blocks, each over the *per-head* dim ``ceil(dim/H)``
    — which is what makes the optimum genuinely head-dependent: at H = 1 a
    large F amortizes step overhead over full-width tiles, while at high H
    the same F pads a narrow per-head dim up to Dblk lanes of mostly-dead
    gather traffic.  ``epilogue=True`` adds the fused-epilogue operand
    reads (per-row scale + per-feature bias — the applied math rides the
    VMEM-resident block for free); ``residual=True`` adds the dense
    (n, d) residual-addend read — one (R, Dblk) tile per (block, j),
    exactly mirroring the output-write traffic (GIN's ``(1+ε)h`` term).
    """
    assert stats.V == config.V and stats.W == config.W
    C, K, slots = stats.chunks_and_slots(config.S, B=config.B)
    dblk = config.dblk
    d_head = _head_dim(dim, heads)
    J = -(-d_head // dblk)
    C *= heads
    n_blocks = stats.n_nonempty_blocks * heads
    steps = J * C * K
    # B-row gathers: one (1, Dblk) tile per step
    bytes_gather = steps * dblk * dtype_bytes
    # per-chunk metadata (vals block + colidx/lrow/trow scalars), per j pass
    bytes_meta = J * C * K * (config.V * 4 + 4 + 4)
    # output blocks written once per (j, block) — revisits stay in VMEM
    bytes_out = J * n_blocks * config.R * dblk * dtype_bytes
    flops = 2.0 * steps * config.V * dblk
    if epilogue:
        # scale (R,) per block + bias (Dblk,) per (block, j): tiny reads
        bytes_meta += (n_blocks * config.R + J * n_blocks * dblk
                       ) * dtype_bytes
        flops += 3.0 * n_blocks * config.R * d_head
    if residual:
        # dense addend: one (R, Dblk) read per (block, j) — the same
        # traffic as the output write
        bytes_meta += J * n_blocks * config.R * dblk * dtype_bytes
        flops += 1.0 * n_blocks * config.R * d_head
    return CostBreakdown(
        t_mem=(bytes_gather + bytes_meta + bytes_out) / HBM_BW,
        t_compute=flops / VPU_FLOPS,
        # chunks are revisited once per dim tile in the (J, C, K) grid, so
        # the per-chunk setup is paid J·C times — the makespan term that
        # prices the balanced schedule's slots-vs-chunks trade
        t_overhead=steps * STEP_OVERHEAD + J * C * CHUNK_SETUP,
        bytes_gather=bytes_gather, bytes_meta=bytes_meta, bytes_out=bytes_out,
        flops=flops, steps=steps, chunk_setups=J * C)


def degraded_kernel_cost(dim: int, config: SpMMConfig, *, C: int, K: int,
                         n_blocks_visited: int,
                         dtype_bytes: int = DTYPE_BYTES, heads: int = 1,
                         epilogue: bool = False,
                         residual: bool = False) -> CostBreakdown:
    """Price the *actual* degraded grid a mutated ``DynamicPCSR`` runs.

    ``kernel_cost`` prices the grid a fresh pack of the current matrix
    would produce; after slack-slot inserts, tombstone deletes, and
    appended delta chunks the live steering arrays execute a different —
    strictly larger — grid.  This variant takes the live extents
    directly (``C`` uncovered chunks of capacity ``K``; the distinct
    blocks those chunks target, which is what bounds output traffic) and
    prices the identical roofline terms, so the governor's
    degraded-vs-fresh comparison and the calibration fit both see the
    same feature columns as every other ``CostBreakdown``.
    """
    dblk = config.dblk
    d_head = _head_dim(dim, heads)
    J = -(-d_head // dblk)
    C = int(C) * heads
    n_blocks = int(n_blocks_visited) * heads
    steps = J * C * K
    bytes_gather = steps * dblk * dtype_bytes
    bytes_meta = J * C * K * (config.V * 4 + 4 + 4)
    bytes_out = J * n_blocks * config.R * dblk * dtype_bytes
    flops = 2.0 * steps * config.V * dblk
    if epilogue:
        bytes_meta += (n_blocks * config.R + J * n_blocks * dblk
                       ) * dtype_bytes
        flops += 3.0 * n_blocks * config.R * d_head
    if residual:
        bytes_meta += J * n_blocks * config.R * dblk * dtype_bytes
        flops += 1.0 * n_blocks * config.R * d_head
    return CostBreakdown(
        t_mem=(bytes_gather + bytes_meta + bytes_out) / HBM_BW,
        t_compute=flops / VPU_FLOPS,
        t_overhead=steps * STEP_OVERHEAD + J * C * CHUNK_SETUP,
        bytes_gather=bytes_gather, bytes_meta=bytes_meta, bytes_out=bytes_out,
        flops=flops, steps=steps, chunk_setups=J * C)


def sddmm_cost(stats: PCSRStats, dim: int, config: SpMMConfig,
               dtype_bytes: int = DTYPE_BYTES, *,
               heads: int = 1) -> CostBreakdown:
    """Price one fused SDDMM(+softmax epilogue) under ⟨W,F,V,S⟩.

    SDDMM is *reduction*-bound where SpMM is scatter-bound: every grid step
    streams a (V, Dblk) query panel AND the gathered (1, Dblk) key row but
    writes almost nothing — the output is one score per slot plus two
    (R,)-row softmax stats per block, independent of ``dim``.  Compute
    still scales with dim (the dot products), so large-F configs trade the
    panel re-reads against MAC-job gap exactly as the paper's coarsening
    analysis predicts — just with the output-traffic term ~absent.
    ``heads`` prices the head-tiled grid over the per-head dim, as in
    ``kernel_cost``.
    """
    assert stats.V == config.V and stats.W == config.W
    C, K, slots = stats.chunks_and_slots(config.S, B=config.B)
    dblk = config.dblk
    d_head = _head_dim(dim, heads)
    J = -(-d_head // dblk)
    C *= heads
    n_blocks = stats.n_nonempty_blocks * heads
    steps = J * C * K
    # per step: the key-row gather (1, Dblk) + the query panel (V, Dblk)
    bytes_gather = steps * (1 + config.V) * dblk * dtype_bytes
    # colidx/lrow scalars per slot + trow/init per chunk + the mask vals
    bytes_meta = C * K * 8 + C * 8 + C * config.V * K * dtype_bytes
    # scores written once per slot; online-softmax stats once per block
    bytes_out = (C * config.V * K
                 + 2 * n_blocks * config.R) * dtype_bytes
    # dot-product MACs + the ~8-op exp/max epilogue per slot row
    flops = 2.0 * steps * config.V * dblk + 8.0 * C * K * config.V
    return CostBreakdown(
        t_mem=(bytes_gather + bytes_meta + bytes_out) / HBM_BW,
        t_compute=flops / VPU_FLOPS,
        # the (C, K, J) grid fetches each chunk's steering/vals once
        t_overhead=steps * STEP_OVERHEAD + C * CHUNK_SETUP,
        bytes_gather=bytes_gather, bytes_meta=bytes_meta, bytes_out=bytes_out,
        flops=flops, steps=steps, chunk_setups=C)


def unfused_bytes(stats: PCSRStats, dim: int, config: SpMMConfig,
                  op: str, dtype_bytes: int = DTYPE_BYTES, *,
                  heads: int = 1) -> float:
    """HBM bytes of the interstitial elementwise passes the fusion layer
    eliminates — the traffic side of ``unfused_penalty``, split out so a
    calibrated model can price it with its *fitted* stream rate instead
    of the hand-set ``HBM_BW``.

    op="gat": the softmax-normalize pass between SDDMM and SpMM —
      read logits + gathered row stats, write α, then the SpMM re-reads α
      instead of logits (a wash), ≈ 3 slot-tensor traversals + the α
      residual write the recompute backward also avoids.
    op="spmm": the separate degree-norm/bias/activation pass(es) over the
      (n, d) output — one read + one write of the full output (XLA fuses
      the elementwise chain into a single pass, so that is what we price).
    """
    C, K, slots = stats.chunks_and_slots(config.S, B=config.B)
    if op == "gat":
        slot_bytes = heads * C * config.V * K * dtype_bytes
        return 3.0 * slot_bytes
    if op == "spmm":
        out_bytes = heads * stats.n_rows * _head_dim(dim, heads) * dtype_bytes
        return 2.0 * out_bytes
    raise ValueError(f"no fusion penalty for op={op!r}")


def unfused_penalty(stats: PCSRStats, dim: int, config: SpMMConfig,
                    op: str, dtype_bytes: int = DTYPE_BYTES, *,
                    heads: int = 1) -> float:
    """Extra seconds the *unfused* pipeline pays vs the fused one — the
    HBM round-trips of ``unfused_bytes`` at the analytic bandwidth.  This
    is the "saved bytes" term that lets the decider treat fusion as a
    config dimension.
    """
    return unfused_bytes(stats, dim, config, op, dtype_bytes,
                         heads=heads) / HBM_BW


class CostModel:
    """Caches per-(V,W) stats for one matrix; prices any config × dim.

    ``op`` selects the operator being priced: ``"spmm"`` (scatter-bound
    kernel), ``"sddmm"`` (reduction-bound kernel), or ``"gat"`` — the
    attention message pipeline, priced as one fused SDDMM+softmax pass plus
    one SpMM aggregation pass, so ``best(..., op="gat")`` picks the config
    minimizing the *pair*, not the SpMM alone.

    ``H`` prices the head-tiled grids over the per-head dim (multi-head
    configs are per-H: high H shrinks the useful lane width, so the
    optimal F — and sometimes V/S — genuinely changes with head count).
    ``fused=False`` adds the interstitial elementwise passes the fusion
    layer removes (``unfused_penalty``), so fused-vs-unfused is a priced
    dimension of the search space, not an assumption.

    ``calibration`` (a ``repro.core.calibrate.CalibrationResult`` — load
    one with ``CostModel.from_calibration``) replaces the hand-set
    constants with coefficients *fitted to measured wall-clock* on this
    host: ``time()`` then prices the same exact grid extents
    (bytes / MACs / steps / chunk setups from ``cost()``) through the
    fitted linear model, so ``best`` — and everything downstream of it:
    the decider's labels, the per-shard distributed config picker, the
    balanced-schedule selection — ranks configs the way this hardware
    measurably does rather than the way the napkin math assumes.
    """

    def __init__(self, csr: CSRMatrix, calibration=None):
        self.csr = csr
        self.calibration = calibration
        self._stats: dict[tuple[int, int], PCSRStats] = {}

    @classmethod
    def from_calibration(cls, csr: CSRMatrix, path) -> "CostModel":
        """Cost model priced by a saved calibration artifact (a JSON path
        or an already-loaded ``CalibrationResult``)."""
        from .calibrate import CalibrationResult
        cal = (path if isinstance(path, CalibrationResult)
               else CalibrationResult.load(path))
        return cls(csr, calibration=cal)

    def stats(self, V: int, W: int) -> PCSRStats:
        key = (V, W)
        if key not in self._stats:
            self._stats[key] = pcsr_stats(self.csr.indptr, self.csr.indices,
                                          self.csr.n_rows, self.csr.n_cols, V, W)
        return self._stats[key]

    def cost(self, dim: int, config: SpMMConfig, op: str = "spmm", *,
             H: int = 1, epilogue: bool = False,
             residual: bool = False) -> CostBreakdown:
        st = self.stats(config.V, config.W)
        if op == "spmm":
            return kernel_cost(st, dim, config, heads=H, epilogue=epilogue,
                               residual=residual)
        if op == "sddmm":
            return sddmm_cost(st, dim, config, heads=H)
        raise ValueError(f"no single-kernel breakdown for op={op!r}")

    def time(self, dim: int, config: SpMMConfig, op: str = "spmm", *,
             H: int = 1, fused: bool = True,
             epilogue: bool = False) -> float:
        """``epilogue=True`` prices a fused-epilogue SpMM (the extra
        scale/bias operand reads); with ``fused=False`` those post-ops run
        as separate passes instead, so the kernel is priced epilogue-free
        and the interstitial-pass penalty is added — the two sides of the
        comparison ``fusion_savings`` takes."""
        if op == "gat":
            t = (self._price(self.cost(dim, config, "sddmm", H=H), "sddmm")
                 + self._price(self.cost(dim, config, "spmm", H=H), "spmm"))
        else:
            t = self._price(self.cost(dim, config, op, H=H,
                                      epilogue=epilogue and fused), op)
        if not fused and op in ("gat", "spmm"):
            st = self.stats(config.V, config.W)
            if self.calibration is None:
                t += unfused_penalty(st, dim, config, op, heads=H)
            else:
                t += self.calibration.stream_seconds(
                    unfused_bytes(st, dim, config, op, heads=H))
        return t

    def _price(self, bd: CostBreakdown, op: str) -> float:
        """Seconds for one kernel pass: the analytic roofline total, or —
        when calibrated — the fitted linear model over the same grid
        extents (``calibrate.breakdown_features``)."""
        if self.calibration is None:
            return bd.total
        return self.calibration.price(bd, op)

    def fusion_savings(self, dim: int, config: SpMMConfig,
                       op: str = "gat", *, H: int = 1) -> float:
        """Seconds the fused pipeline saves over the unfused one — for
        op="spmm" the fused side pays the epilogue operand reads, the
        unfused side the separate elementwise passes."""
        return (self.time(dim, config, op, H=H, fused=False)
                - self.time(dim, config, op, H=H, fused=True,
                            epilogue=op == "spmm"))

    def best(self, dim: int, space, op: str = "spmm", *, H: int = 1,
             fused: bool = True) -> tuple[SpMMConfig, float]:
        best_cfg, best_t = None, np.inf
        scored = []
        for cfg in space:
            t = self.time(dim, cfg, op, H=H, fused=fused)
            scored.append((cfg, t))
            if t < best_t:
                best_cfg, best_t = cfg, t
        if _obs_trace.trace_enabled() and best_cfg is not None:
            _obs_decisions.record_decision(
                self.csr, source="cost_model", op=op, dim=dim, heads=H,
                chosen=best_cfg, predicted_seconds=best_t,
                candidates=scored, calibration=self.calibration)
        return best_cfg, best_t


def useful_flops(nnz: int, dim: int) -> float:
    """MAC count of the mathematical SpMM (2·nnz·dim)."""
    return 2.0 * nnz * dim


# --------------------------------------------------- distributed terms
ICI_BW = 100e9            # B/s per-link interconnect (TPU v5e ICI, ~1D ring)
COLLECTIVE_LATENCY = 1e-6  # s per collective not hidden by compute


def halo_exchange_cost(gathered_rows: int, dim: int,
                       dtype_bytes: int = DTYPE_BYTES) -> float:
    """Seconds one compacted halo ``all_gather`` keeps the interconnect
    busy: every shard receives the full ``(P·max_send, dim)`` send
    buffer, so the wire time is its byte count over the ICI bandwidth
    plus a fixed collective-launch latency.  This is the term the
    overlap path (``DistGraph(overlap=True)``) hides behind the
    shard-local SpMM — ``bench_dist`` reports it next to the local
    sub-matrix's predicted compute time so the "is the gather actually
    hideable" question is priced, not assumed."""
    return (gathered_rows * dim * dtype_bytes) / ICI_BW + COLLECTIVE_LATENCY


def overlap_exposed_cost(local_time: float, halo_time: float,
                         exchange_time: float) -> float:
    """Predicted per-shard step time under the overlap decomposition:
    the gather runs concurrently with the local SpMM (whichever is
    longer bounds), then the halo sub-SpMM runs on the landed rows.
    Compare against ``local_time + halo_time + exchange_time`` (the
    serialized schedule) for the predicted overlap win."""
    return max(local_time, exchange_time) + halo_time
