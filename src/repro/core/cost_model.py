"""Analytical TPU-v5e cost model for the ParamSpMM kernel.

This is the napkin-math layer the perf loop reasons with (DESIGN.md §6) and
the label source for decider training at corpus scale.  It prices the exact
grid the kernel would execute — per (V,W) block populations come from
``pcsr_stats`` so every padding effect the paper discusses is priced, not
approximated:

  * V padding (PR_V)      → more slots when vectors are half-empty;
  * S chunk padding       → slots = Σ_b ceil(cnt_b/K)·K;
  * F MAC-job gap         → J·Dblk ≥ dim lane waste;
  * W scatter granularity → output-block traffic ∝ blocks touched.

Hardware constants (TPU v5e, from the assignment + public specs):
  197 TFLOP/s bf16 MXU — NOT the unit here: SpMM MACs run on the VPU;
  we assume 8 sublanes × 128 lanes × 2 FMA × 0.94 GHz ≈ 1.9 TFLOP/s f32.
  HBM 819 GB/s; per-step DMA issue overhead ~100 ns (double-buffered).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .pcsr import SpMMConfig, PCSRStats, pcsr_stats, LANES
from .sparse import CSRMatrix

HBM_BW = 819e9            # B/s
VPU_FLOPS = 1.9e12        # f32 FMA/s (VPU, not MXU)
STEP_OVERHEAD = 100e-9    # s per grid step not hidden by double buffering
DTYPE_BYTES = 4


@dataclass
class CostBreakdown:
    t_mem: float
    t_compute: float
    t_overhead: float
    bytes_gather: float
    bytes_meta: float
    bytes_out: float
    flops: float
    steps: int

    @property
    def total(self) -> float:
        return max(self.t_mem, self.t_compute) + self.t_overhead

    @property
    def bytes_total(self) -> float:
        return self.bytes_gather + self.bytes_meta + self.bytes_out


def kernel_cost(stats: PCSRStats, dim: int, config: SpMMConfig,
                dtype_bytes: int = DTYPE_BYTES) -> CostBreakdown:
    """Price one SpMM under ⟨W,F,V,S⟩ given (V,W)-matched block stats."""
    assert stats.V == config.V and stats.W == config.W
    C, K, slots = stats.chunks_and_slots(config.S)
    dblk = config.dblk
    J = -(-dim // dblk)
    steps = J * C * K
    # B-row gathers: one (1, Dblk) tile per step
    bytes_gather = steps * dblk * dtype_bytes
    # per-chunk metadata (vals block + colidx/lrow/trow scalars), per j pass
    bytes_meta = J * C * K * (config.V * 4 + 4 + 4)
    # output blocks written once per (j, block) — revisits stay in VMEM
    bytes_out = J * stats.n_nonempty_blocks * config.R * dblk * dtype_bytes
    flops = 2.0 * steps * config.V * dblk
    return CostBreakdown(
        t_mem=(bytes_gather + bytes_meta + bytes_out) / HBM_BW,
        t_compute=flops / VPU_FLOPS,
        t_overhead=steps * STEP_OVERHEAD,
        bytes_gather=bytes_gather, bytes_meta=bytes_meta, bytes_out=bytes_out,
        flops=flops, steps=steps)


def sddmm_cost(stats: PCSRStats, dim: int, config: SpMMConfig,
               dtype_bytes: int = DTYPE_BYTES) -> CostBreakdown:
    """Price one fused SDDMM(+softmax epilogue) under ⟨W,F,V,S⟩.

    SDDMM is *reduction*-bound where SpMM is scatter-bound: every grid step
    streams a (V, Dblk) query panel AND the gathered (1, Dblk) key row but
    writes almost nothing — the output is one score per slot plus two
    (R,)-row softmax stats per block, independent of ``dim``.  Compute
    still scales with dim (the dot products), so large-F configs trade the
    panel re-reads against MAC-job gap exactly as the paper's coarsening
    analysis predicts — just with the output-traffic term ~absent.
    """
    assert stats.V == config.V and stats.W == config.W
    C, K, slots = stats.chunks_and_slots(config.S)
    dblk = config.dblk
    J = -(-dim // dblk)
    steps = J * C * K
    # per step: the key-row gather (1, Dblk) + the query panel (V, Dblk)
    bytes_gather = steps * (1 + config.V) * dblk * dtype_bytes
    # colidx/lrow scalars per slot + trow/init per chunk + the mask vals
    bytes_meta = C * K * 8 + C * 8 + C * config.V * K * dtype_bytes
    # scores written once per slot; online-softmax stats once per block
    bytes_out = (C * config.V * K
                 + 2 * stats.n_nonempty_blocks * config.R) * dtype_bytes
    # dot-product MACs + the ~8-op exp/max epilogue per slot row
    flops = 2.0 * steps * config.V * dblk + 8.0 * C * K * config.V
    return CostBreakdown(
        t_mem=(bytes_gather + bytes_meta + bytes_out) / HBM_BW,
        t_compute=flops / VPU_FLOPS,
        t_overhead=steps * STEP_OVERHEAD,
        bytes_gather=bytes_gather, bytes_meta=bytes_meta, bytes_out=bytes_out,
        flops=flops, steps=steps)


class CostModel:
    """Caches per-(V,W) stats for one matrix; prices any config × dim.

    ``op`` selects the operator being priced: ``"spmm"`` (scatter-bound
    kernel), ``"sddmm"`` (reduction-bound kernel), or ``"gat"`` — the
    attention message pipeline, priced as one fused SDDMM+softmax pass plus
    one SpMM aggregation pass, so ``best(..., op="gat")`` picks the config
    minimizing the *pair*, not the SpMM alone.
    """

    def __init__(self, csr: CSRMatrix):
        self.csr = csr
        self._stats: dict[tuple[int, int], PCSRStats] = {}

    def stats(self, V: int, W: int) -> PCSRStats:
        key = (V, W)
        if key not in self._stats:
            self._stats[key] = pcsr_stats(self.csr.indptr, self.csr.indices,
                                          self.csr.n_rows, self.csr.n_cols, V, W)
        return self._stats[key]

    def cost(self, dim: int, config: SpMMConfig,
             op: str = "spmm") -> CostBreakdown:
        st = self.stats(config.V, config.W)
        if op == "spmm":
            return kernel_cost(st, dim, config)
        if op == "sddmm":
            return sddmm_cost(st, dim, config)
        raise ValueError(f"no single-kernel breakdown for op={op!r}")

    def time(self, dim: int, config: SpMMConfig, op: str = "spmm") -> float:
        if op == "gat":
            return (self.cost(dim, config, "sddmm").total
                    + self.cost(dim, config, "spmm").total)
        return self.cost(dim, config, op).total

    def best(self, dim: int, space,
             op: str = "spmm") -> tuple[SpMMConfig, float]:
        best_cfg, best_t = None, np.inf
        for cfg in space:
            t = self.time(dim, cfg, op)
            if t < best_t:
                best_cfg, best_t = cfg, t
        return best_cfg, best_t


def useful_flops(nnz: int, dim: int) -> float:
    """MAC count of the mathematical SpMM (2·nnz·dim)."""
    return 2.0 * nnz * dim
