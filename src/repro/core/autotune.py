"""Oracle config search: exhaustive timing/pricing of the config space.

Two modes:
  * "model"    — analytical TPU cost model (corpus-scale label source);
  * "measured" — wall-clock of the jit'd JAX engine on this host, with B
    padded to the F-tile so the MAC-job gap is physically paid.  CPU time
    is a proxy (no per-step DMA overhead), used to validate the model's
    ranking on a subset (EXPERIMENTS.md records both).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import (decisions as _obs_decisions, metrics as _obs_metrics,
                       trace as _obs_trace)

from .cost_model import CostModel
from .pcsr import SpMMConfig, build_pcsr, config_space
from .sparse import CSRMatrix


def time_fn(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    _obs_metrics.counter("autotune_measurements_total").inc(reps)
    return float(np.median(ts))


@dataclass
class OracleResult:
    times: dict            # config -> seconds
    best_config: SpMMConfig
    best_time: float


def oracle_search(csr: CSRMatrix, dim: int, space=None, mode: str = "model",
                  reps: int = 3, rng_seed: int = 0,
                  cm: CostModel | None = None,
                  op: str = "spmm", H: int = 1,
                  calibration=None) -> OracleResult:
    """Exhaustive search of ``space`` for operator ``op`` ("spmm",
    "sddmm", or "gat" — the SDDMM+softmax+SpMM attention pair, timed or
    priced as the sum of its two passes).

    ``H`` is the head count the labels are collected FOR: multi-head
    layers run the head-tiled grid over the *per-head* dim ``ceil(dim/H)``
    (see ``kernel_cost``), so the optimal config genuinely shifts with H
    — a search pinned at H=1 labels multi-head GAT deciders for the wrong
    problem.  Model mode prices ``cm.time(..., H=H)``; measured mode
    times the engine on the actual head-tiled steering arrays
    (``PCSR.steering(H)``) with per-head-dim operands.

    ``calibration`` (a ``CalibrationResult`` or a path to a saved
    artifact) makes model mode price through fitted-to-hardware
    coefficients instead of the hand-set constants — the label source
    the decider should be trained on once a host has been calibrated.
    Ignored when an explicit ``cm`` is passed (build that cost model
    with the calibration instead) and in measured mode (measured times
    need no pricing).
    """
    if op not in ("spmm", "sddmm", "gat"):
        raise ValueError(op)
    if H < 1:
        raise ValueError(f"H must be ≥ 1, got {H}")
    space = space or config_space(dim)
    with _obs_trace.span("oracle.search", mode=mode, op=op, dim=dim, H=H,
                         n_configs=len(space)):
        times = _oracle_times(csr, dim, space, mode, reps, rng_seed, cm,
                              op, H, calibration)
    best = min(times, key=times.get)
    if _obs_trace.trace_enabled():
        _obs_decisions.record_decision(
            csr, source=f"oracle_{mode}", op=op, dim=dim, heads=H,
            chosen=best, predicted_seconds=times[best],
            candidates=times.items(),
            calibration=cm.calibration if cm is not None else calibration)
    return OracleResult(times, best, times[best])


def _oracle_times(csr, dim, space, mode, reps, rng_seed, cm, op, H,
                  calibration) -> dict:
    times = {}
    if mode == "model":
        if cm is None:
            if calibration is not None and not hasattr(calibration, "price"):
                from .calibrate import CalibrationResult
                calibration = CalibrationResult.load(calibration)
            cm = CostModel(csr, calibration=calibration)
        for cfg in space:
            times[cfg] = cm.time(dim, cfg, op, H=H)
    elif mode == "measured":
        from .engine import _engine, _engine_sddmm

        rng = np.random.default_rng(rng_seed)
        d_head = -(-dim // H)
        for cfg in space:
            dim_pad = -(-d_head // cfg.dblk) * cfg.dblk
            B = jnp.asarray(
                rng.standard_normal((H * csr.n_cols, dim_pad)), jnp.float32)
            pcsr = build_pcsr(csr.indptr, csr.indices, csr.data,
                              csr.n_rows, csr.n_cols, cfg)
            st = pcsr.steering(H)
            colidx, lrow, trow, vals = (
                jnp.asarray(st[k]) for k in ("colidx", "lrow", "trow", "vals"))
            t = 0.0
            if op in ("spmm", "gat"):
                t += time_fn(
                    lambda: _engine(colidx, lrow, trow, vals, B, V=cfg.V,
                                    R=cfg.R, K=pcsr.K,
                                    n_blocks=H * pcsr.n_blocks,
                                    n_rows=H * pcsr.n_blocks * cfg.R),
                    reps=reps)
            if op in ("sddmm", "gat"):
                Q = jnp.asarray(
                    rng.standard_normal((H * pcsr.n_blocks * cfg.R, dim_pad)),
                    jnp.float32)
                t += time_fn(
                    lambda: _engine_sddmm(colidx, lrow, trow, vals, Q, B,
                                          V=cfg.V, R=cfg.R, K=pcsr.K),
                    reps=reps)
            times[cfg] = t
    else:
        raise ValueError(mode)
    return times


def throughput_gflops(csr: CSRMatrix, dim: int, seconds: float) -> float:
    """Useful GFLOP/s (2·nnz·dim MACs), the paper's reporting unit."""
    return 2.0 * csr.nnz * dim / seconds / 1e9
