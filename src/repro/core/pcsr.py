"""Parameterized Compressed Sparse Row (PCSR) — TPU adaptation.

The paper's PCSR stores ``rowPtr/colIdx/val/TRow`` parameterized by
⟨W, F, V, S⟩ (Section 4.2).  On TPU the format is re-derived for a
sequential Pallas grid (see DESIGN.md §2):

* nonzeros are grouped into ``V×1`` column-vectors inside V-row *panels*
  (vectorized blocking — one gathered row of ``B`` feeds V output rows);
* ``W`` panels form an output *block* of ``R = V·W`` rows (the unit the
  kernel accumulates in VMEM);
* each block's vectors are packed into fixed-capacity *chunks* of ``K``
  slots.  ``S=False`` → row-aligned chunks with capacity ≈ the maximum
  block population (the static-grid analogue of "one warp per row");
  ``S=True`` → capacity ``K = SG`` derived from the mean population
  (the paper's Split Granularity, Eq. 3, with warp-size roundup replaced
  by sublane roundup), so heavy blocks split across several chunks that
  the kernel accumulates via consecutive output-block revisits (the
  TPU analogue of the paper's ``TRow`` + ``atomicAdd``);
* ``B=True`` (requires ``S=True``) → the *nnz-balanced* schedule: the
  capacity comes from ``balanced_capacity`` (a search over the block
  population *distribution*, not just its mean), each block's vectors
  are round-robined across its chunks so per-chunk nnz is near-uniform
  (no mostly-empty tail chunk), and chunks are emitted in LPT order
  (descending block population, each block's chunks contiguous — the
  ``fini``/VMEM-revisit machinery only needs *grouped* ``trow``, not
  ascending).  On a power-law graph this removes most of the padding
  slots the mean-derived ``SG`` wastes on the long tail of light
  blocks — the total-slot count is the sequential grid's makespan.

Everything here is host-side preprocessing in vectorized numpy — the
paper performs PCSR generation on the host as well, amortized across
training iterations.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from time import perf_counter

import numpy as np

from repro.obs import metrics as _obs_metrics, trace as _obs_trace

LANES = 128          # TPU lane width (the paper's warp size ω=32 analogue)
SUBLANES = 8         # f32 sublane quantum

# Memory guard for the unbalanced mode: a power-law max-degree block would
# otherwise pad *every* chunk to the global max.  Capping keeps host memory
# bounded while preserving the skew penalty the paper attributes to S=False.
UNBALANCED_CAP = 8192


def _round_up(x: int, m: int) -> int:
    return -(-int(x) // m) * m


@dataclass(frozen=True)
class SpMMConfig:
    """The paper's ⟨W, F, V, S⟩ tuple, plus the TPU ``B`` (balanced) axis.

    V: vector size of blocking (paper domain {1, 2}).
    S: workload balancing on/off.
    F: coarsening factor — dim-tile width ``Dblk = F·128`` lanes.
    W: panels per output block — block height ``R = V·W`` rows.
    B: nnz-balanced chunk schedule (distribution-derived capacity +
       round-robin slot packing + LPT chunk order).  Requires ``S=True``
       — balancing is a refinement of the split-chunk layout; the kernel
       is unchanged, only the steering arrays differ.
    """

    V: int = 1
    S: bool = False
    F: int = 1
    W: int = 8
    B: bool = False

    def __post_init__(self):
        if self.V < 1 or self.F < 1 or self.W < 1:
            raise ValueError(f"invalid config {self}")
        if self.B and not self.S:
            raise ValueError(f"B=True requires S=True ({self})")

    @property
    def R(self) -> int:
        return self.V * self.W

    @property
    def dblk(self) -> int:
        return self.F * LANES

    def astuple(self):
        return (self.W, self.F, self.V, self.S, self.B)

    def replace(self, **kw) -> "SpMMConfig":
        return dataclasses.replace(self, **kw)


def config_space(dim: int, max_f: int = 4):
    """Enumerate the search domain for a given embedding dim.

    V ∈ {1,2} (paper limits V to {1,2}: V=3 pads >50% on 97.5% of graphs);
    S ∈ {False,True}; F ∈ [1, CEIL(dim/128)] (the paper's
    F ∈ [1, CEIL(dim/ω)] with ω=128 on TPU); R = V·W ∈ {8,16,32}.

    Balanced (``B=True``, implies ``S=True``) variants are appended AFTER
    the uniform configs so an exact price tie — the degenerate case on
    uniform-degree graphs, where ``balanced_capacity`` lands on the same
    ``K`` as the mean-derived SG — resolves to the uniform layout under
    ``CostModel.best``'s strict ``<``.
    """
    fs = list(range(1, min(max_f, _round_up(dim, LANES) // LANES) + 1))
    out = []
    for v in (1, 2):
        for s in (False, True):
            for f in fs:
                for r in (8, 16, 32):
                    out.append(SpMMConfig(V=v, S=s, F=f, W=r // v))
    for v in (1, 2):
        for f in fs:
            for r in (8, 16, 32):
                out.append(SpMMConfig(V=v, S=True, F=f, W=r // v, B=True))
    return out


@dataclass
class PCSR:
    """Packed PCSR arrays (numpy, host-resident) + bookkeeping stats."""

    config: SpMMConfig
    n_rows: int            # rows of A (= rows of C)
    n_cols: int            # cols of A (= rows of B)
    n_blocks: int          # output blocks of R rows each
    K: int                 # chunk capacity (slots)
    colidx: np.ndarray     # (C·K,) int32 — B-row per slot (pad → 0)
    lrow: np.ndarray       # (C·K,) int32 — panel idx within block
    trow: np.ndarray       # (C,)   int32 — target block per chunk
    init: np.ndarray       # (C,)   int32 — 1 iff first chunk of its block
    vals: np.ndarray       # (C,V,K) float32 — vector values (pad → 0)
    nnz: int
    nnz_vec: int           # number of nonzero vectors
    n_nonempty_blocks: int

    @property
    def num_chunks(self) -> int:
        return int(self.trow.shape[0])

    @property
    def num_slots(self) -> int:
        return self.num_chunks * self.K

    @property
    def padding_ratio(self) -> float:
        """PR_V (paper Eq. 2): 1 - nnz / (nnz_V · V)."""
        if self.nnz_vec == 0:
            return 0.0
        return 1.0 - self.nnz / (self.nnz_vec * self.config.V)

    @property
    def split_ratio(self) -> float:
        """SR (paper Eq. 4): reassigned-rowPtr length over original."""
        return self.num_chunks / max(1, self.n_nonempty_blocks)

    @property
    def slot_fill(self) -> float:
        """Fraction of chunk slots holding a real vector."""
        return self.nnz_vec / max(1, self.num_slots)

    def nbytes(self) -> int:
        return (self.colidx.nbytes + self.lrow.nbytes + self.trow.nbytes
                + self.init.nbytes + self.vals.nbytes)

    def to_jax(self):
        """Device-ready uncovered H=1 arrays, routed through the
        ``steering()`` cache so every backend shares one pack accessor
        (and its hit/miss accounting)."""
        import jax.numpy as jnp
        st = self.steering()
        return {k: jnp.asarray(st[k])
                for k in ("colidx", "lrow", "trow", "init", "vals")}

    @property
    def fini(self) -> np.ndarray:
        """(C,) int32 — 1 iff the chunk is the LAST chunk of its block.

        The mirror of ``init``: where ``init`` steers the kernel's
        zero-on-first-visit, ``fini`` steers the fused *epilogue* — the
        last ``(j, k)`` step of a block is the one moment the completed
        ``(R, Dblk)`` output tile is still VMEM-resident, so scale/bias/
        activation can be applied for free before write-back.  ``trow`` is
        *grouped* by construction — each block's chunks are contiguous
        (ascending in the uniform modes, LPT order under ``B=True``) — so
        the last chunk of each block is the one whose successor targets a
        different block.
        """
        f = self.__dict__.get("_fini")
        if f is None:
            f = np.ones(self.num_chunks, np.int32)
            f[:-1] = (self.trow[1:] != self.trow[:-1]).astype(np.int32)
            self.__dict__["_fini"] = f
        return f

    @property
    def n_empty_blocks(self) -> int:
        """Blocks no chunk targets (their coverage chunks — see
        ``steering(covered=True)`` — are all-padding)."""
        return self.n_blocks - len(np.unique(self.trow))

    @property
    def covered_num_chunks(self) -> int:
        """Per-head chunk count of the *covered* steering arrays
        (``num_chunks`` real chunks + one all-padding coverage chunk per
        empty block).  The distributed branches slice the mesh-packed
        covered arrays with this; the per-head layout puts the real
        chunks first (prefix property), so ``[:num_chunks]`` of each
        head's segment recovers the uncovered arrays."""
        return self.num_chunks + self.n_empty_blocks

    def steering(self, H: int = 1, covered: bool = False):
        """Steering arrays for the kernels (cached per (H, covered)).

        ``H > 1`` tiles the chunk list for an H-head batch: ``colidx`` is
        offset by ``h·n_cols`` (heads stacked along the gather source's row
        axis) and ``trow`` by ``h·n_blocks`` (heads stacked along the
        output's block axis), so ONE kernel call — and one compilation —
        covers every head instead of a per-head ``vmap``.

        ``covered=True`` appends one all-padding chunk per *empty* block
        (``init = fini = 1``, ``vals = 0``) so the sequential grid visits —
        and therefore zero-initializes — every output block.  This folds
        the unvisited-block zeroing into the kernel's own ``init`` path:
        no post-kernel O(n_blocks·R·dim) elementwise mask pass remains,
        and the fused epilogue (bias on empty rows!) applies uniformly.
        The appended chunks come LAST, so the first ``C·K`` entries of a
        covered array are exactly the uncovered ones (prefix property the
        distributed packing relies on).
        """
        cache = self.__dict__.setdefault("_steering_cache", {})
        key = (H, covered)
        if key in cache:
            _obs_metrics.counter("pack_cache_hits_total").inc(
                H=H, covered=covered)
            return cache[key]
        _obs_metrics.counter("pack_cache_misses_total").inc(
            H=H, covered=covered)
        colidx, lrow = self.colidx, self.lrow
        trow, init, fini, vals = self.trow, self.init, self.fini, self.vals
        if covered:
            empty = np.setdiff1d(np.arange(self.n_blocks, dtype=np.int64),
                                 trow.astype(np.int64))
            E = len(empty)
            if E:
                colidx = np.concatenate([colidx, np.zeros(E * self.K, np.int32)])
                lrow = np.concatenate([lrow, np.zeros(E * self.K, np.int32)])
                trow = np.concatenate([trow, empty.astype(np.int32)])
                init = np.concatenate([init, np.ones(E, np.int32)])
                fini = np.concatenate([fini, np.ones(E, np.int32)])
                vals = np.concatenate(
                    [vals, np.zeros((E, self.config.V, self.K), np.float32)])
        if H > 1:
            hh = np.arange(H, dtype=np.int64)
            colidx = (np.tile(colidx, (H, 1))
                      + (hh * self.n_cols)[:, None]).reshape(-1).astype(np.int32)
            trow = (np.tile(trow, (H, 1))
                    + (hh * self.n_blocks)[:, None]).reshape(-1).astype(np.int32)
            lrow, init, fini = (np.tile(a, H) for a in (lrow, init, fini))
            vals = np.tile(vals, (H, 1, 1))
        cache[key] = {"colidx": colidx, "lrow": lrow, "trow": trow,
                      "init": init, "fini": fini, "vals": vals}
        return cache[key]

    def head_tiled(self, H: int):
        """Back-compat alias for ``steering(H)`` (uncovered arrays)."""
        return self.steering(H)


def _vectorize(indptr, indices, data, n_rows, n_cols, V):
    """Group nonzeros into V×1 panel vectors.

    Returns (vec_panel, vec_col, vec_val[nv, V]) sorted by (panel, col).
    """
    nnz = int(indices.shape[0])
    if nnz == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros((0, V), np.float32))
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr))
    panel = rows // V
    off = (rows - panel * V).astype(np.int64)
    key = panel * n_cols + indices.astype(np.int64)
    ukey, inv = np.unique(key, return_inverse=True)
    vec_val = np.zeros((ukey.shape[0], V), np.float32)
    # canonical CSR has unique (row, col); direct assignment is exact.
    vec_val[inv, off] = data.astype(np.float32)
    return ukey // n_cols, ukey % n_cols, vec_val


def split_granularity(nnz_vec: int, n_nonempty_blocks: int) -> int:
    """Paper Eq. 3: SG = CEILDIV(d̂_V, ω)·ω, sublane-aligned on TPU."""
    mean = -(-max(1, nnz_vec) // max(1, n_nonempty_blocks))
    return max(SUBLANES, _round_up(mean, SUBLANES))


# Chunks a balanced schedule is willing to add per removed slot-octet: the
# capacity search charges each extra chunk as ``BALANCE_LAMBDA`` padding
# slots, mirroring the cost model's per-chunk ``CHUNK_SETUP`` overhead
# (steering fetch + vals DMA issue) so the packer and the pricing agree on
# when splitting finer stops paying.
BALANCE_LAMBDA = 4.0


def balanced_capacity(counts, lam: float = BALANCE_LAMBDA,
                      unbalanced_cap: int = UNBALANCED_CAP) -> int:
    """Chunk capacity minimizing ``slots(K) + lam · chunks(K)`` over the
    block-population *distribution* (the mean-derived SG of Eq. 3 only
    sees its first moment).

    ``slots(K) = Σ_b ceil(cnt_b/K)·K`` is the sequential grid's makespan
    (every slot is one grid step, padding included); ``chunks(K)`` prices
    per-chunk setup.  Candidates are the sublane roundups of the
    population quantiles + mean — O(1) evaluations of an O(n_blocks)
    objective, deterministic, and within a sublane of the true optimum on
    every corpus family (the objective is piecewise-linear between
    population order statistics).
    """
    counts = np.asarray(counts, np.int64)
    counts = counts[counts > 0]
    if counts.size == 0:
        return SUBLANES
    qs = np.quantile(counts, [0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0])
    cand = {max(SUBLANES, min(_round_up(int(q), SUBLANES),
                              _round_up(unbalanced_cap, SUBLANES)))
            for q in np.concatenate([qs, [counts.mean()]])}
    best_k, best_obj = SUBLANES, np.inf
    for K in sorted(cand):
        nch = -(-counts // K)
        C = int(nch.sum())
        obj = C * K + lam * C
        if obj < best_obj:
            best_k, best_obj = K, obj
    return best_k


def build_pcsr(indptr, indices, data, n_rows, n_cols,
               config: SpMMConfig, unbalanced_cap: int = UNBALANCED_CAP,
               capacity: int | None = None) -> PCSR:
    """PCSR generation (paper §4.2), fully vectorized.

    ``config.B`` selects the nnz-balanced packer: capacity from
    ``balanced_capacity``, each block's vectors round-robined across its
    chunks (per-chunk nnz within a block differs by ≤ 1 — a fat row's
    vectors split evenly over every chunk instead of filling chunks
    left-to-right and leaving a mostly-padding tail), chunks emitted in
    LPT (descending block population) order with each block's chunks
    contiguous.  Downstream machinery only relies on *grouped* ``trow``
    (``fini``/consecutive-revisit accumulation), never on ascending
    order, so the schedule needs no kernel change.

    ``capacity`` pins the chunk capacity ``K`` (sublane-rounded) instead
    of deriving it from the matrix — the serving tier uses this so every
    graph packed into one shape bucket shares the bucket's fixed chunk
    geometry (and therefore one compiled kernel).
    """
    if not _obs_trace.trace_enabled():
        return _build_pcsr(indptr, indices, data, n_rows, n_cols,
                           config, unbalanced_cap, capacity)
    with _obs_trace.span("pcsr.build", config=str(config.astuple()),
                         n_rows=int(n_rows),
                         nnz=int(np.asarray(indices).shape[0])):
        t0 = perf_counter()
        p = _build_pcsr(indptr, indices, data, n_rows, n_cols,
                        config, unbalanced_cap, capacity)
        _obs_metrics.histogram("pack_build_seconds").observe(
            perf_counter() - t0, config=str(config.astuple()))
    return p


def _build_pcsr(indptr, indices, data, n_rows, n_cols,
                config: SpMMConfig, unbalanced_cap: int,
                capacity: int | None = None) -> PCSR:
    V, W, S, Bal = config.V, config.W, config.S, config.B
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    data = np.asarray(data)
    nnz = int(indices.shape[0])
    n_panels = max(1, _round_up(n_rows, V) // V)
    n_blocks = max(1, _round_up(n_panels, W) // W)

    vec_panel, vec_col, vec_val = _vectorize(indptr, indices, data,
                                             n_rows, n_cols, V)
    nv = int(vec_panel.shape[0])
    bid = vec_panel // W                      # block of each vector (sorted)
    lrow_vec = (vec_panel - bid * W).astype(np.int32)
    counts = np.bincount(bid.astype(np.int64), minlength=n_blocks) if nv \
        else np.zeros(n_blocks, np.int64)
    nonempty = int((counts > 0).sum())

    if capacity is not None:
        K = max(SUBLANES, _round_up(capacity, SUBLANES))
    elif Bal:
        K = balanced_capacity(counts, unbalanced_cap=unbalanced_cap)
    elif S:
        K = split_granularity(nv, nonempty)
    else:
        K = min(_round_up(max(1, counts.max() if nv else 1), SUBLANES),
                _round_up(unbalanced_cap, SUBLANES))

    nch = -(-counts // K)                     # chunks per block (0 if empty)
    C = int(nch.sum())
    if C == 0:                                # degenerate: all-zero matrix
        return PCSR(config, n_rows, n_cols, n_blocks, K,
                    np.zeros(K, np.int32), np.zeros(K, np.int32),
                    np.zeros(1, np.int32), np.ones(1, np.int32),
                    np.zeros((1, V, K), np.float32), nnz, nv, nonempty)

    # emitted block order: ascending for the uniform modes, LPT
    # (descending population, stable) for the balanced schedule
    border = (np.argsort(-counts, kind="stable") if Bal
              else np.arange(n_blocks, dtype=np.int64))
    nch_ord = nch[border]
    starts_ord = np.concatenate([[0], np.cumsum(nch_ord)])
    first_chunk = np.empty(n_blocks, np.int64)
    first_chunk[border] = starts_ord[:-1]     # block id → its first chunk
    trow = np.repeat(border, nch_ord).astype(np.int32)
    init = np.zeros(C, np.int32)
    init[starts_ord[:-1][nch_ord > 0]] = 1

    # slot of each vector: rank within its block → (chunk, slot)
    block_vec_start = np.concatenate([[0], np.cumsum(counts)])
    rank = np.arange(nv, dtype=np.int64) - block_vec_start[bid]
    if Bal:
        # round-robin: every chunk of the block gets ceil- or floor-even
        # share of its vectors → near-uniform per-chunk nnz
        chunk_g = first_chunk[bid] + rank % nch[bid]
        slot = rank // nch[bid]
    else:
        chunk_g = first_chunk[bid] + rank // K
        slot = rank % K

    colidx = np.zeros(C * K, np.int32)
    lrow = np.zeros(C * K, np.int32)
    vals = np.zeros((C, V, K), np.float32)
    pos = chunk_g * K + slot
    colidx[pos] = vec_col.astype(np.int32)
    lrow[pos] = lrow_vec
    vals[chunk_g[:, None], np.arange(V)[None, :], slot[:, None]] = vec_val
    return PCSR(config, n_rows, n_cols, n_blocks, K, colidx, lrow,
                trow, init, vals, nnz, nv, nonempty)


def pad_pcsr(p: PCSR, *, n_rows: int, n_cols: int | None = None,
             num_chunks: int | None = None) -> PCSR:
    """Pad a PCSR to a fixed bucket shape (serving tier).

    Returns a PCSR whose geometry is exactly ``(n_rows, n_cols,
    num_chunks)`` regardless of the input graph, so every request packed
    into one shape bucket produces bit-identical steering-array *shapes*
    — the precondition for one compiled kernel per bucket.  Three kinds
    of chunks are appended after the real ones (prefix property — the
    original chunks come first, verbatim):

    1. one all-padding *coverage* chunk per empty block (``init=1``,
       ascending block id) — the same chunks ``steering(covered=True)``
       would synthesize, materialized eagerly so the padded PCSR has
       **zero** empty blocks and covered == uncovered steering;
    2. ``num_chunks - C - E`` *filler* chunks (``init=0``, all padding)
       targeting the last empty block — they re-visit an already-zeroed
       block and accumulate nothing, bringing the chunk count to the
       bucket ceiling.

    The grouped-``trow`` invariant is preserved (filler directly follows
    its block's coverage chunk), so the lazily recomputed ``fini`` fires
    the fused epilogue exactly once per block.  Row padding relies on the
    caller leaving headroom: callers must size ``n_rows`` so at least one
    block is empty whenever filler is needed (the serve bucket geometry
    adds one always-empty trailing block for exactly this).
    """
    cfg = p.config
    n_cols = n_rows if n_cols is None else n_cols
    if n_rows < p.n_rows or n_cols < p.n_cols:
        raise ValueError(
            f"pad_pcsr target ({n_rows}x{n_cols}) smaller than "
            f"packed matrix ({p.n_rows}x{p.n_cols})")
    n_panels = max(1, _round_up(n_rows, cfg.V) // cfg.V)
    n_blocks = max(1, _round_up(n_panels, cfg.W) // cfg.W)
    covered = np.unique(p.trow.astype(np.int64))
    empty = np.setdiff1d(np.arange(n_blocks, dtype=np.int64), covered)
    E = int(empty.size)
    C = p.num_chunks
    target = C + E if num_chunks is None else int(num_chunks)
    filler = target - C - E
    if filler < 0:
        raise ValueError(
            f"pad_pcsr chunk budget {target} < required {C + E} "
            f"(C={C} real + E={E} coverage)")
    if filler > 0 and E == 0:
        raise ValueError(
            "pad_pcsr needs an empty block to host filler chunks — "
            "size the bucket with at least one spare row block")
    pad = E + filler
    if pad == 0:
        out = PCSR(cfg, n_rows, n_cols, n_blocks, p.K, p.colidx, p.lrow,
                   p.trow, p.init, p.vals, p.nnz, p.nnz_vec,
                   p.n_nonempty_blocks)
        return out
    trow_pad = np.concatenate(
        [empty, np.full(filler, empty[-1] if E else 0, np.int64)])
    trow = np.concatenate([p.trow, trow_pad.astype(np.int32)])
    init = np.concatenate(
        [p.init, np.ones(E, np.int32), np.zeros(filler, np.int32)])
    colidx = np.concatenate([p.colidx, np.zeros(pad * p.K, np.int32)])
    lrow = np.concatenate([p.lrow, np.zeros(pad * p.K, np.int32)])
    vals = np.concatenate(
        [p.vals, np.zeros((pad, cfg.V, p.K), np.float32)])
    return PCSR(cfg, n_rows, n_cols, n_blocks, p.K, colidx, lrow,
                trow, init, vals, p.nnz, p.nnz_vec, p.n_nonempty_blocks)


@dataclass
class PCSRStats:
    """Exact per-(V, W) block-population stats — enough to cost every
    (S, F) choice without materializing the packed arrays."""

    n_rows: int
    n_cols: int
    nnz: int
    V: int
    W: int
    nnz_vec: int
    n_blocks: int
    n_nonempty_blocks: int
    max_block: int
    mean_block: float
    counts_hist: np.ndarray   # per-nonempty-block vector counts

    def chunks_and_slots(self, S: bool, unbalanced_cap: int = UNBALANCED_CAP,
                         B: bool = False):
        """(C, K, slots) of the layout ⟨S, B⟩ would pack — the exact grid
        extents the cost model prices.  ``B=True`` runs the same
        ``balanced_capacity`` search the packer runs, so pricing and
        packing cannot disagree about the balanced chunk geometry."""
        if self.n_nonempty_blocks == 0:
            return 1, SUBLANES, SUBLANES
        if B:
            K = balanced_capacity(self.counts_hist,
                                  unbalanced_cap=unbalanced_cap)
        elif S:
            K = split_granularity(self.nnz_vec, self.n_nonempty_blocks)
        else:
            K = min(_round_up(max(1, self.max_block), SUBLANES),
                    _round_up(unbalanced_cap, SUBLANES))
        nch = -(-self.counts_hist // K)
        C = int(nch.sum())
        return C, K, C * K

    @property
    def padding_ratio(self) -> float:
        if self.nnz_vec == 0:
            return 0.0
        return 1.0 - self.nnz / (self.nnz_vec * self.V)


def pcsr_stats(indptr, indices, n_rows, n_cols, V: int, W: int) -> PCSRStats:
    """Vectorization + block statistics only (cost model / features path)."""
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    nnz = int(indices.shape[0])
    n_panels = max(1, _round_up(n_rows, V) // V)
    n_blocks = max(1, _round_up(n_panels, W) // W)
    if nnz == 0:
        return PCSRStats(n_rows, n_cols, 0, V, W, 0, n_blocks, 0, 0, 0.0,
                         np.zeros(0, np.int64))
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr))
    key = (rows // V) * n_cols + indices
    ukey = np.unique(key)
    bid = (ukey // n_cols) // W
    counts = np.bincount(bid, minlength=n_blocks)
    ne = counts[counts > 0]
    return PCSRStats(n_rows, n_cols, nnz, V, W, int(ukey.shape[0]), n_blocks,
                     int(ne.shape[0]), int(ne.max()), float(ne.mean()),
                     ne.astype(np.int64))


def transpose_csr(indptr, indices, data, n_rows, n_cols):
    """CSR of Aᵀ (for the backward SpMM dB = Aᵀ·dC)."""
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    data = np.asarray(data)
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr))
    order = np.argsort(indices, kind="stable")
    t_counts = np.bincount(indices, minlength=n_cols)
    t_indptr = np.concatenate([[0], np.cumsum(t_counts)]).astype(np.int64)
    return t_indptr, rows[order], data[order], n_cols, n_rows


def pcsr_slot_coords(p: PCSR):
    """Dense coordinates of every *real* slot entry (stored value ≠ 0).

    Returns ``(rows, cols, flat)`` — the (row, col) of each edge plus its
    flat index into ``vals.reshape(-1)``, the (C, V, K) slot tensor order.
    """
    c, v, k = np.nonzero(p.vals)
    ck = c * p.K + k
    rows = (p.trow[c].astype(np.int64) * p.config.R
            + p.lrow[ck].astype(np.int64) * p.config.V + v)
    cols = p.colidx[ck].astype(np.int64)
    flat = (c * p.config.V + v) * p.K + k
    return rows, cols, flat


def pcsr_to_coo(p: PCSR):
    """Recover the (rows, cols, vals) edge list packed into a PCSR."""
    rows, cols, flat = pcsr_slot_coords(p)
    return rows, cols, p.vals.reshape(-1)[flat]


def transpose_pcsr(p: PCSR, config: SpMMConfig | None = None) -> PCSR:
    """PCSR of Aᵀ under the same (or a given) ⟨W,F,V,S⟩ configuration.

    Built once from the forward PCSR's own edge list (no original CSR
    needed) via ``transpose_csr``-style counting; used by the dedicated GAT
    backward for the ``dK``/``dVf`` SpMMs.
    """
    rows, cols, vals = pcsr_to_coo(p)
    order = np.lexsort((rows, cols))           # CSR of Aᵀ: sort by (col, row)
    t_indptr = np.concatenate(
        [[0], np.cumsum(np.bincount(cols, minlength=p.n_cols))]).astype(np.int64)
    return build_pcsr(t_indptr, rows[order], vals[order],
                      p.n_cols, p.n_rows, config or p.config)


def slot_transfer_map(p: PCSR, p_t: PCSR):
    """Flat-index pair moving per-edge slot values A-layout → Aᵀ-layout.

    For each edge (i, j) of A, ``f_idx`` is its flat position in ``p``'s
    (C, V, K) slot tensor and ``t_idx`` its flat position in ``p_t``'s —
    so ``t.reshape(-1).at[t_idx].set(f.reshape(-1)[f_idx])`` re-lays a slot
    tensor (e.g. softmaxed attention weights) onto the transpose PCSR.
    Padding slots on either side are untouched (they stay zero).
    """
    rows, cols, f_flat = pcsr_slot_coords(p)
    t_rows, t_cols, t_flat = pcsr_slot_coords(p_t)
    key_f = rows * p.n_cols + cols
    key_t = t_cols * p.n_cols + t_rows        # Aᵀ edge (j, i) ↔ A edge (i, j)
    of, ot = np.argsort(key_f), np.argsort(key_t)
    if not np.array_equal(key_f[of], key_t[ot]):
        raise ValueError("PCSR pair does not pack the same edge set")
    return f_flat[of].astype(np.int32), t_flat[ot].astype(np.int32)
