"""Cost-model calibration: fit priced time to measured time.

The analytic cost model (``cost_model.py``) prices the exact grid a
config would execute, but its *constants* — ``HBM_BW``, ``VPU_FLOPS``,
``STEP_OVERHEAD``, ``CHUNK_SETUP`` — are hand-set from TPU-v5e specs.
On any real host (including the CPU engine the benchmarks time) those
numbers are wrong in both magnitude and ratio, which is why
BENCH_spmm.json's adaptive gains sit at ~1.000×: the decider, the
per-shard distributed picker, and the balanced-schedule selection all
rank configs by prices no measurement ever validated.

This module closes that loop:

1. **Design** (``build_design``): run ``autotune.time_fn`` — via
   ``oracle_search(mode="measured")`` — over a (graph × config × dim ×
   op) design drawn from the corpus, and record next to each measured
   wall-clock the *feature columns* of the priced grid: the constant
   (per-call dispatch), bytes moved, MAC jobs, grid steps, and chunk
   setups (``CostBreakdown.chunk_setups``).  Each hard-coded constant of
   ``kernel_cost``/``sddmm_cost`` is exactly one column's coefficient.
2. **Fit** (``fit`` / ``fit_columns``): non-negative least squares
   (Lawson–Hanson, numpy-only) on relative residuals — timing samples
   span orders of magnitude, so the fit weights each sample by 1/t to
   optimize the *relative* error that rank quality depends on.
   Non-negativity keeps every coefficient physically meaningful
   (seconds per byte, per FLOP, per step, per chunk).
3. **Artifact** (``CalibrationResult.save/load``): a JSON file (checked
   into ``configs/``) that ``CostModel.from_calibration`` consumes —
   ``CostModel.time`` then prices through the fitted coefficients, and
   everything downstream of ``CostModel.best`` inherits honest prices.

``spearman`` + ``gate_design`` are the verification half: the pinned
small-corpus design the rank-correlation regression gate
(``tests/test_calibration.py``) and ``benchmarks/bench_calibration.py``
both run, so "the model ranks configs like the hardware does" is an
asserted invariant, not a hope.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from .cost_model import (CHUNK_SETUP, HBM_BW, STEP_OVERHEAD, VPU_FLOPS,
                         CostBreakdown, CostModel)
from .pcsr import SpMMConfig, config_space

__all__ = [
    "COLUMNS", "GATE_GRAPHS", "GATE_DIMS", "GATE_REPS",
    "CalibrationSample", "CalibrationResult",
    "breakdown_features", "reference_coefficients",
    "nnls", "fit_columns", "fit", "spearman",
    "build_design", "gate_design", "run_calibration",
]

# Feature columns of the fit — one per additive cost term.  The analytic
# model's constants are exactly these columns' reference coefficients
# (``reference_coefficients``); the fit replaces them with measured ones.
COLUMNS = ("const", "bytes", "flops", "steps", "chunks")

# The pinned rank-correlation gate design: 3 graphs of ``corpus("small")``
# spanning power-law / uniform / preferential-attachment degree
# distributions, 2 dims, seeded measured oracle with pinned reps — small
# enough for tier-1, diverse enough that Spearman ρ over it means
# something.  tests/test_calibration.py and bench_calibration both use it.
GATE_GRAPHS = ("rmat10", "er1k", "ba1k")
GATE_DIMS = (32, 64)
GATE_REPS = 3


def reference_coefficients() -> dict:
    """The hand-set analytic constants as fit coefficients — the
    "pre-calibration" point every fit is compared against (``const`` is 0:
    the analytic model prices no per-call dispatch)."""
    return {"const": 0.0, "bytes": 1.0 / HBM_BW, "flops": 1.0 / VPU_FLOPS,
            "steps": STEP_OVERHEAD, "chunks": CHUNK_SETUP}


def breakdown_features(bd: CostBreakdown) -> np.ndarray:
    """Feature vector of one priced kernel pass, in ``COLUMNS`` order."""
    return np.array([1.0, bd.bytes_total, bd.flops, float(bd.steps),
                     float(bd.chunk_setups)], np.float64)


# ------------------------------------------------------------------ fit
def nnls(A, b, max_iter: int | None = None) -> np.ndarray:
    """Non-negative least squares ``min ‖Ax − b‖₂ s.t. x ≥ 0`` —
    Lawson–Hanson active-set, numpy-only (the repo vendors instead of
    depending on scipy)."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    m, n = A.shape
    x = np.zeros(n)
    P = np.zeros(n, bool)                    # active (positive) set
    w = A.T @ (b - A @ x)                    # dual / gradient
    tol = 10 * np.finfo(np.float64).eps * np.linalg.norm(A, 1) * max(m, n)
    max_iter = max_iter or 3 * n
    it = 0
    while (~P).any() and np.max(np.where(~P, w, -np.inf)) > tol:
        P[int(np.argmax(np.where(~P, w, -np.inf)))] = True
        while True:
            z = np.zeros(n)
            z[P] = np.linalg.lstsq(A[:, P], b, rcond=None)[0]
            if np.min(z[P]) > 0:
                break
            mask = P & (z <= 0)
            alpha = np.min(x[mask] / (x[mask] - z[mask]))
            x = x + alpha * (z - x)
            P[x <= tol] = False
            it += 1
            if it > max_iter:
                break
        x = z.copy()
        x[~P] = 0.0
        w = A.T @ (b - A @ x)
        it += 1
        if it > max_iter:
            break
    return x


def fit_columns(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """NNLS coefficients of ``y ≈ X @ coef`` on *relative* residuals.

    Rows are weighted by ``1/y`` (minimize Σ((ŷ−y)/y)² — a 10 µs miss on
    a 20 µs call matters as much as a 10 ms miss on a 20 ms call), and
    columns are max-scaled before the solve so the active-set pivoting is
    not dominated by the raw magnitude spread (bytes ~1e6 vs const 1).
    """
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    w = 1.0 / np.maximum(y, 1e-12)
    Xw = X * w[:, None]
    scale = Xw.max(axis=0)
    scale[scale == 0] = 1.0
    return nnls(Xw / scale, np.ones_like(y)) / scale


def spearman(x, y) -> float:
    """Spearman rank correlation (average ranks on ties, numpy-only) —
    the "does the price order configs like the hardware" metric every
    speed claim is gated on."""
    def rank(a):
        a = np.asarray(a, np.float64)
        order = np.argsort(a, kind="stable")
        s = a[order]
        new_grp = np.concatenate([[True], s[1:] != s[:-1]])
        grp = np.cumsum(new_grp) - 1
        counts = np.bincount(grp)
        csum = np.concatenate([[0], np.cumsum(counts)])
        avg = (csum[:-1] + csum[1:] - 1) / 2.0 + 1
        out = np.empty(a.shape[0])
        out[order] = avg[grp]
        return out

    rx, ry = rank(x), rank(y)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx * rx).sum() * (ry * ry).sum())
    if denom == 0:
        return 0.0
    return float((rx * ry).sum() / denom)


# --------------------------------------------------------------- design
@dataclass
class CalibrationSample:
    """One (graph × op × dim × config) cell of the measured design."""

    graph: str
    op: str
    dim: int
    config: tuple                 # SpMMConfig.astuple() — JSON-friendly
    features: np.ndarray          # (len(COLUMNS),) priced grid extents
    measured: float               # seconds (median of pinned reps)
    priced: float                 # analytic CostModel.time (pre-calibration)


def build_design(graphs, dims=GATE_DIMS, ops=("spmm",), space=None,
                 reps: int = GATE_REPS, rng_seed: int = 0, H: int = 1,
                 verbose: bool = False) -> list[CalibrationSample]:
    """Measured (graph × config × dim × op) design over the corpus.

    ``graphs`` is a list of ``repro.data.graphs.GraphSpec``.  Every cell
    times the jit'd engine via ``oracle_search(mode="measured")`` (which
    uses ``autotune.time_fn``: median of ``reps`` with warmup) and prices
    the same cell's grid extents into ``features`` — the matched pair the
    fit and the rank gate both consume.
    """
    from .autotune import oracle_search

    samples: list[CalibrationSample] = []
    for g in graphs:
        cm = CostModel(g.csr)
        for dim in dims:
            sp = space or config_space(dim)
            for op in ops:
                res = oracle_search(g.csr, dim, space=sp, mode="measured",
                                    reps=reps, rng_seed=rng_seed, op=op, H=H)
                for cfg in sp:
                    bd = cm.cost(dim, cfg, op, H=H)
                    samples.append(CalibrationSample(
                        g.name, op, dim, cfg.astuple(),
                        breakdown_features(bd), res.times[cfg],
                        cm.time(dim, cfg, op, H=H)))
            if verbose:
                print(f"  design: {g.name} dim={dim} "
                      f"({len(samples)} samples)")
    return samples


def gate_design(reps: int = GATE_REPS) -> list[CalibrationSample]:
    """The pinned small-corpus design behind the rank-correlation
    regression gate: ``GATE_GRAPHS`` × ``GATE_DIMS`` × the full config
    space, op="spmm", seeded operands, ``reps`` pinned."""
    from repro.data.graphs import corpus

    graphs = [g for g in corpus("small") if g.name in GATE_GRAPHS]
    assert len(graphs) == len(GATE_GRAPHS)
    return build_design(graphs, dims=GATE_DIMS, ops=("spmm",), reps=reps)


# ------------------------------------------------------------- artifact
@dataclass
class CalibrationResult:
    """Fitted per-op coefficients + fit provenance.

    ``coef`` maps op → {column → seconds-per-unit}.  Ops are fitted
    separately (a CPU SpMM engine and a CPU SDDMM engine have genuinely
    different efficiency), and an op missing from the fit falls back to
    the "spmm" coefficients.  ``meta`` records the design (graphs, dims,
    reps, host) and in-sample diagnostics (per-op Spearman ρ, n).
    """

    coef: dict
    meta: dict = field(default_factory=dict)

    def coefficients(self, op: str = "spmm") -> np.ndarray:
        c = self.coef.get(op) or self.coef.get("spmm") \
            or next(iter(self.coef.values()))
        return np.array([c[name] for name in COLUMNS], np.float64)

    def price(self, bd: CostBreakdown, op: str = "spmm") -> float:
        """Seconds of one kernel pass under the fitted model."""
        return float(breakdown_features(bd) @ self.coefficients(op))

    def stream_seconds(self, nbytes: float, op: str = "spmm") -> float:
        """Seconds to stream ``nbytes`` of pure elementwise traffic (the
        unfused interstitial passes).  Uses the fitted bytes coefficient;
        when the fit zeroed it (a compute-bound host hides byte traffic
        behind MACs), fall back to the analytic bandwidth so the penalty
        never silently vanishes."""
        c = float(self.coefficients(op)[COLUMNS.index("bytes")])
        return nbytes * (c if c > 0 else 1.0 / HBM_BW)

    def predict(self, samples) -> np.ndarray:
        return np.array([s.features @ self.coefficients(s.op)
                         for s in samples])

    # ------------------------------------------------------ persistence
    def to_dict(self) -> dict:
        return {"columns": list(COLUMNS), "coef": self.coef,
                "meta": self.meta}

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationResult":
        if list(d.get("columns", [])) != list(COLUMNS):
            raise ValueError(
                f"calibration artifact columns {d.get('columns')} do not "
                f"match this build's {list(COLUMNS)}")
        return cls(coef=d["coef"], meta=d.get("meta", {}))

    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")

    @classmethod
    def load(cls, path) -> "CalibrationResult":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


def fit(samples, meta: dict | None = None) -> CalibrationResult:
    """Per-op weighted NNLS over a measured design, with in-sample
    diagnostics (Spearman ρ pre/post, n) recorded into ``meta``."""
    by_op: dict[str, list[CalibrationSample]] = {}
    for s in samples:
        by_op.setdefault(s.op, []).append(s)
    coef, diag = {}, {}
    for op, ss in sorted(by_op.items()):
        X = np.stack([s.features for s in ss])
        y = np.array([s.measured for s in ss])
        c = fit_columns(X, y)
        coef[op] = dict(zip(COLUMNS, c.tolist()))
        diag[op] = {
            "n": len(ss),
            "rho_pre": spearman([s.priced for s in ss], y),
            "rho_post": spearman(X @ c, y),
        }
    out_meta = dict(meta or {})
    out_meta["diagnostics"] = diag
    return CalibrationResult(coef=coef, meta=out_meta)


# ------------------------------------------------------------------ CLI
def run_calibration(scale: str = "small", dims=GATE_DIMS,
                    ops=("spmm", "sddmm"), reps: int = GATE_REPS,
                    max_nnz: int = 150_000, max_graphs: int | None = None,
                    out: str | None = None, verbose: bool = False):
    """End-to-end calibration pass: corpus tier → measured design → fit
    → (optionally) saved JSON artifact.  Returns (result, samples)."""
    import platform

    from repro.data.graphs import corpus

    graphs = [g for g in corpus(scale) if g.csr.nnz <= max_nnz]
    if max_graphs:
        graphs = graphs[:max_graphs]
    samples = build_design(graphs, dims=dims, ops=ops, reps=reps,
                           verbose=verbose)
    result = fit(samples, meta={
        "scale": scale, "graphs": [g.name for g in graphs],
        "dims": list(dims), "ops": list(ops), "reps": reps,
        "host": platform.platform(),
        "backend": _jax_backend(),
    })
    if out:
        result.save(out)
        if verbose:
            print(f"wrote {out}")
    return result, samples


def _jax_backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:                                    # pragma: no cover
        return "unknown"


def main(argv=None):                                     # pragma: no cover
    import argparse

    ap = argparse.ArgumentParser(
        description="Fit the cost model's constants to measured kernel "
        "time and save the calibration artifact")
    ap.add_argument("--scale", default="small",
                    choices=["small", "skewed", "bench", "large"])
    ap.add_argument("--dims", default=None,
                    help="comma-separated dims (default: 32,64)")
    ap.add_argument("--ops", default="spmm,sddmm")
    ap.add_argument("--reps", type=int, default=GATE_REPS)
    ap.add_argument("--max-nnz", type=int, default=150_000)
    ap.add_argument("--fast", action="store_true",
                    help="tiny design: 2 graphs, one dim, 2 reps (CI "
                    "smoke)")
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here (e.g. "
                    "configs/calibration_cpu_host.json)")
    args = ap.parse_args(argv)

    dims = (tuple(int(d) for d in args.dims.split(","))
            if args.dims else GATE_DIMS)
    kw = dict(scale=args.scale, dims=dims,
              ops=tuple(args.ops.split(",")), reps=args.reps,
              max_nnz=args.max_nnz, out=args.out, verbose=True)
    if args.fast:
        kw.update(dims=dims[:1], reps=2, max_graphs=2)
    result, samples = run_calibration(**kw)
    for op, d in result.meta["diagnostics"].items():
        print(f"{op}: n={d['n']} rho_pre={d['rho_pre']:.3f} "
              f"rho_post={d['rho_post']:.3f}")
    for op, c in result.coef.items():
        print(f"{op} coefficients: " + " ".join(
            f"{k}={v:.3e}" for k, v in c.items()))


if __name__ == "__main__":                               # pragma: no cover
    main()
