"""Minimal CSR container used across the framework (no scipy in env)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRMatrix:
    indptr: np.ndarray     # (n_rows+1,) int64
    indices: np.ndarray    # (nnz,) int64, column ids
    data: np.ndarray       # (nnz,) float32
    n_rows: int
    n_cols: int

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def shape(self):
        return (self.n_rows, self.n_cols)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @staticmethod
    def from_coo(rows, cols, vals, n_rows, n_cols, sum_duplicates=True) -> "CSRMatrix":
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals, np.float32)
        key = rows * n_cols + cols
        order = np.argsort(key, kind="stable")
        key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
        if sum_duplicates and key.shape[0]:
            uniq, start = np.unique(key, return_index=True)
            seg = np.repeat(np.arange(uniq.shape[0]), np.diff(
                np.concatenate([start, [key.shape[0]]])))
            summed = np.zeros(uniq.shape[0], np.float32)
            np.add.at(summed, seg, vals)
            rows, cols, vals = uniq // n_cols, uniq % n_cols, summed
        counts = np.bincount(rows, minlength=n_rows)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return CSRMatrix(indptr, cols.astype(np.int64), vals, n_rows, n_cols)

    @staticmethod
    def from_dense(A) -> "CSRMatrix":
        A = np.asarray(A)
        rows, cols = np.nonzero(A)
        return CSRMatrix.from_coo(rows, cols, A[rows, cols].astype(np.float32),
                                  A.shape[0], A.shape[1], sum_duplicates=False)

    @staticmethod
    def from_edges(src, dst, n, vals=None, symmetrize=False) -> "CSRMatrix":
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if vals is None:
            vals = np.ones(src.shape[0], np.float32)
        return CSRMatrix.from_coo(src, dst, vals, n, n)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        rows = np.repeat(np.arange(self.n_rows), self.degrees)
        out[rows, self.indices] = self.data
        return out

    def transpose(self) -> "CSRMatrix":
        from .pcsr import transpose_csr
        ip, ix, d, nr, nc = transpose_csr(self.indptr, self.indices, self.data,
                                          self.n_rows, self.n_cols)
        return CSRMatrix(ip, ix, d, nr, nc)

    def permute(self, perm: np.ndarray) -> "CSRMatrix":
        """Symmetric permutation A' = P A Pᵀ: node i → position perm[i]."""
        assert self.n_rows == self.n_cols
        rows = np.repeat(np.arange(self.n_rows), self.degrees)
        return CSRMatrix.from_coo(perm[rows], perm[self.indices], self.data,
                                  self.n_rows, self.n_cols, sum_duplicates=False)

    def row_normalize(self) -> "CSRMatrix":
        deg = np.maximum(self.degrees, 1).astype(np.float32)
        rows = np.repeat(np.arange(self.n_rows), self.degrees)
        return CSRMatrix(self.indptr, self.indices,
                         (self.data / deg[rows]).astype(np.float32),
                         self.n_rows, self.n_cols)

    def gcn_normalize(self) -> "CSRMatrix":
        """Â = D^{-1/2}(A+I)D^{-1/2} (GCN propagation matrix)."""
        assert self.n_rows == self.n_cols
        rows = np.repeat(np.arange(self.n_rows), self.degrees)
        rows = np.concatenate([rows, np.arange(self.n_rows)])
        cols = np.concatenate([self.indices, np.arange(self.n_rows)])
        vals = np.concatenate([self.data, np.ones(self.n_rows, np.float32)])
        m = CSRMatrix.from_coo(rows, cols, vals, self.n_rows, self.n_cols)
        deg = np.maximum(np.diff(m.indptr), 1).astype(np.float32)
        dinv = 1.0 / np.sqrt(deg)
        r2 = np.repeat(np.arange(m.n_rows), np.diff(m.indptr))
        return CSRMatrix(m.indptr, m.indices,
                         (m.data * dinv[r2] * dinv[m.indices]).astype(np.float32),
                         m.n_rows, m.n_cols)
