"""Sparse-matrix features for the SpMM-decider (paper Table 3).

Three categories: size (n, n̂, nnz, r, d, d̂, d_max), degree distribution
(CV, ĈV, SR_i, bal_i), data locality (ρ, bw_avg, bw_max, PR_i).  Features
are a function of the sparse matrix only — measured once, reused across
``dim`` (the paper's amortization argument).  ``dim`` itself is appended
at prediction time so one model serves all dims.

``bal_1``/``bal_2`` are the balanced-schedule slot savings — the fraction
of grid slots the ``B=True`` layout removes relative to the mean-SG
split layout at V=1/V=2 — the direct predictor of when the decider
should pick a balanced config (high CV ⇒ high bal_i).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .pcsr import pcsr_stats, split_granularity, SUBLANES
from .sparse import CSRMatrix

FEATURE_NAMES = [
    "n", "n_hat", "nnz", "r", "d", "d_hat", "d_max",          # size
    "cv", "cv_hat", "sr_1", "sr_2",                           # degree dist
    "rho", "bw_avg", "bw_max", "pr_1", "pr_2",                # locality
    "bal_1", "bal_2",                     # balanced-schedule slot savings
]


@dataclass
class MatrixFeatures:
    values: np.ndarray          # (len(FEATURE_NAMES),) float64

    def as_dict(self):
        return dict(zip(FEATURE_NAMES, self.values.tolist()))

    def vector(self, dim: int | None = None) -> np.ndarray:
        """Feature vector for the decider; log-compress the size features
        so forests split on relative rather than absolute scale."""
        v = self.values.copy()
        for i in (0, 1, 2, 4, 5, 6, 12, 13):    # n, n̂, nnz, d, d̂, dmax, bw
            v[i] = np.log1p(v[i])
        if dim is not None:
            v = np.concatenate([v, [float(dim)]])
        return v


def _split_ratio(csr: CSRMatrix, V: int) -> float:
    """SR under ⟨V, S=True⟩ (paper Eq. 4), at the reference W = 8/V."""
    st = pcsr_stats(csr.indptr, csr.indices, csr.n_rows, csr.n_cols,
                    V, max(1, 8 // V))
    C, _, _ = st.chunks_and_slots(S=True)
    return C / max(1, st.n_nonempty_blocks)


def _balanced_gain(csr: CSRMatrix, V: int) -> float:
    """Slot savings of the ⟨V, S=True, B=True⟩ layout over ⟨V, S=True⟩ at
    the reference W = 8/V: ``1 − slots_B/slots_S``.  ≈ 0 on uniform-degree
    graphs (the capacity search lands on the mean-SG layout), grows with
    degree CV — the feature the decider splits on to pick ``B``."""
    st = pcsr_stats(csr.indptr, csr.indices, csr.n_rows, csr.n_cols,
                    V, max(1, 8 // V))
    _, _, slots_s = st.chunks_and_slots(S=True)
    _, _, slots_b = st.chunks_and_slots(S=True, B=True)
    return 1.0 - slots_b / max(1, slots_s)


def extract_features(csr: CSRMatrix) -> MatrixFeatures:
    n = csr.n_rows
    deg = csr.degrees.astype(np.float64)
    nnz = csr.nnz
    n_hat = int((deg > 0).sum())
    d = nnz / max(1, n)
    d_hat = nnz / max(1, n_hat)
    d_max = float(deg.max()) if n else 0.0
    cv = float(deg.std() / d) if d > 0 else 0.0
    deg_ne = deg[deg > 0]
    cv_hat = float(deg_ne.std() / d_hat) if n_hat else 0.0
    rho = nnz / max(1, n * csr.n_cols)
    # row bandwidth: last col − first col per non-empty row
    if nnz:
        starts = csr.indptr[:-1][deg > 0]
        ends = csr.indptr[1:][deg > 0] - 1
        bw = (csr.indices[ends] - csr.indices[starts]).astype(np.float64)
        bw_avg, bw_max = float(bw.mean()), float(bw.max())
    else:
        bw_avg = bw_max = 0.0
    st2 = pcsr_stats(csr.indptr, csr.indices, csr.n_rows, csr.n_cols, 2, 4)
    pr_2 = st2.padding_ratio
    vals = np.array([n, n_hat, nnz, n_hat / max(1, n), d, d_hat, d_max,
                     cv, cv_hat, _split_ratio(csr, 1), _split_ratio(csr, 2),
                     rho, bw_avg, bw_max, 0.0, pr_2,
                     _balanced_gain(csr, 1), _balanced_gain(csr, 2)],
                    np.float64)
    return MatrixFeatures(vals)
