"""Graph reordering to enhance data locality (paper §4.4).

The paper uses Rabbit Reordering (community detection + locality-aware ID
assignment) as default preprocessing: nodes with shared neighbors get close
IDs, creating consecutive same-column nonzeros for V=2 blocking (lower PR_2)
and denser row bandwidth.  We implement the same *algorithmic role* with a
deterministic two-level scheme (DESIGN.md §2): clustered BFS over the
highest-degree seeds (communities = BFS trees capped at a size budget,
mirroring Rabbit's hierarchical merging cutoff) with intra-community
ordering by discovery, which is exactly the amortizable host-side step the
paper describes.  A degree-sort baseline and identity are provided for the
reordering ablation (paper Table 6).
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix


def rabbit_reorder(csr: CSRMatrix, community_budget: int | None = None,
                   seed: int = 0) -> np.ndarray:
    """Locality-aware ordering portfolio (Rabbit's role, DESIGN.md §2):
    community-clustered BFS (connected-locality) AND neighbor-signature
    sort (similar-neighbor locality, the co-citation structure V=2
    exploits) — returns whichever yields the lower PR_2."""
    from .pcsr import pcsr_stats

    def pr2(c):
        return pcsr_stats(c.indptr, c.indices, c.n_rows, c.n_cols,
                          2, 4).padding_ratio

    cands = [bfs_cluster_reorder(csr, community_budget, seed),
             similarity_reorder(csr)]
    best, best_pr = None, np.inf
    for perm in cands:
        p = pr2(apply_reorder(csr, perm))
        if p < best_pr:
            best, best_pr = perm, p
    return best


def similarity_reorder(csr: CSRMatrix) -> np.ndarray:
    """Sort rows by a neighbor-set signature (3 smallest neighbor ids +
    degree): rows with near-identical neighborhoods become adjacent —
    exactly what vectorized blocking needs, even when those rows are not
    connected to each other (directed co-citation)."""
    n = csr.n_rows
    deg = csr.degrees
    sig = np.full((n, 3), csr.n_cols, np.int64)
    for j in range(3):
        has = deg > j
        sig[has, j] = csr.indices[csr.indptr[:-1][has] + j]
    order = np.lexsort((deg, sig[:, 2], sig[:, 1], sig[:, 0]))
    perm = np.empty(n, np.int64)
    perm[order] = np.arange(n)
    return perm


def bfs_cluster_reorder(csr: CSRMatrix, community_budget: int | None = None,
                        seed: int = 0) -> np.ndarray:
    """Return perm with node i → new ID perm[i] (community-clustered BFS)."""
    n = csr.n_rows
    if n == 0:
        return np.zeros(0, np.int64)
    if community_budget is None:
        community_budget = max(64, int(np.sqrt(csr.nnz + 1)))
    from collections import deque

    deg = csr.degrees
    order_seed = np.argsort(-deg, kind="stable")     # high-degree seeds first
    visited = np.zeros(n, bool)
    perm = np.empty(n, np.int64)
    nxt = 0
    indptr, indices = csr.indptr, csr.indices
    for s in order_seed:
        if visited[s]:
            continue
        # BFS from s; stop *expanding* at the community budget but always
        # drain the queue so every visited node receives an ID.
        q = deque([int(s)])
        visited[s] = True
        count = 0
        while q:
            u = q.popleft()
            perm[u] = nxt
            nxt += 1
            count += 1
            if count < community_budget:
                for v in indices[indptr[u]:indptr[u + 1]]:
                    if not visited[v]:
                        visited[v] = True
                        q.append(int(v))
    assert nxt == n
    return perm


def degree_reorder(csr: CSRMatrix) -> np.ndarray:
    """Descending-degree relabel (cheap locality baseline)."""
    order = np.argsort(-csr.degrees, kind="stable")
    perm = np.empty(csr.n_rows, np.int64)
    perm[order] = np.arange(csr.n_rows)
    return perm


def identity_order(csr: CSRMatrix) -> np.ndarray:
    return np.arange(csr.n_rows, dtype=np.int64)


def apply_reorder(csr: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    return csr.permute(perm)
