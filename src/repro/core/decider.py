"""ML-based SpMM-decider (paper §5): a random forest over Table-3 features
predicting the optimal ⟨W,F,V,S⟩.  Re-implemented in numpy (no sklearn in
this environment): CART trees with gini impurity, bootstrap sampling, and
per-split feature subsampling — the standard random-forest recipe the
paper relies on for its "lightweight, low-overfitting-risk" argument.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field

import numpy as np

from repro.obs import decisions as _obs_decisions, trace as _obs_trace

from .features import MatrixFeatures, extract_features
from .pcsr import SpMMConfig, config_space
from .sparse import CSRMatrix


# ------------------------------------------------------------------ trees
class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self, value=None):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = value            # class-probability vector at leaves


class DecisionTree:
    def __init__(self, max_depth=14, min_samples_leaf=2, max_features=None,
                 rng=None):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)
        self.n_classes = 0
        self.root = None

    def fit(self, X, y, n_classes):
        self.n_classes = n_classes
        self.root = self._grow(np.asarray(X, np.float64),
                               np.asarray(y, np.int64), 0)
        return self

    def _leaf(self, y):
        counts = np.bincount(y, minlength=self.n_classes).astype(np.float64)
        return _Node(value=counts / max(1.0, counts.sum()))

    def _gini(self, y):
        if y.shape[0] == 0:
            return 0.0
        p = np.bincount(y, minlength=self.n_classes) / y.shape[0]
        return 1.0 - (p * p).sum()

    def _grow(self, X, y, depth):
        n, nf = X.shape
        if (depth >= self.max_depth or n < 2 * self.min_samples_leaf
                or np.unique(y).shape[0] == 1):
            return self._leaf(y)
        k = self.max_features or max(1, int(np.sqrt(nf)))
        feats = self.rng.choice(nf, size=min(k, nf), replace=False)
        best = (None, None, np.inf)
        parent_gini = self._gini(y)
        for f in feats:
            xs = X[:, f]
            order = np.argsort(xs, kind="stable")
            xs_s, y_s = xs[order], y[order]
            # candidate thresholds at class-boundary midpoints (subsampled)
            uniq = np.unique(xs_s)
            if uniq.shape[0] < 2:
                continue
            cand = (uniq[:-1] + uniq[1:]) / 2.0
            if cand.shape[0] > 32:
                cand = cand[np.linspace(0, cand.shape[0] - 1, 32, dtype=int)]
            for thr in cand:
                mask = xs <= thr
                nl = int(mask.sum())
                if nl < self.min_samples_leaf or n - nl < self.min_samples_leaf:
                    continue
                g = (nl * self._gini(y[mask])
                     + (n - nl) * self._gini(y[~mask])) / n
                if g < best[2]:
                    best = (f, thr, g)
        if best[0] is None or best[2] >= parent_gini - 1e-12:
            return self._leaf(y)
        f, thr, _ = best
        mask = X[:, f] <= thr
        node = _Node()
        node.feature, node.threshold = int(f), float(thr)
        node.left = self._grow(X[mask], y[mask], depth + 1)
        node.right = self._grow(X[~mask], y[~mask], depth + 1)
        return node

    def predict_proba(self, X):
        X = np.asarray(X, np.float64)
        out = np.empty((X.shape[0], self.n_classes))
        for i, x in enumerate(X):
            node = self.root
            while node.value is None:
                node = node.left if x[node.feature] <= node.threshold else node.right
            out[i] = node.value
        return out


class RandomForest:
    def __init__(self, n_estimators=60, max_depth=14, min_samples_leaf=2,
                 seed=0):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.trees: list[DecisionTree] = []
        self.n_classes = 0

    def fit(self, X, y, n_classes):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.int64)
        self.n_classes = n_classes
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for _ in range(self.n_estimators):
            idx = rng.integers(0, X.shape[0], X.shape[0])   # bootstrap
            t = DecisionTree(self.max_depth, self.min_samples_leaf,
                             rng=np.random.default_rng(rng.integers(2**31)))
            t.fit(X[idx], y[idx], n_classes)
            self.trees.append(t)
        return self

    def predict_proba(self, X):
        p = np.zeros((np.asarray(X).shape[0], self.n_classes))
        for t in self.trees:
            p += t.predict_proba(X)
        return p / len(self.trees)

    def predict(self, X):
        return self.predict_proba(X).argmax(axis=1)


# ---------------------------------------------------------------- decider
@dataclass
class SpMMDecider:
    """Predicts ⟨W,F,V,S⟩ from matrix features (+dim appended)."""

    space: list = field(default_factory=lambda: config_space(512, max_f=4))
    forest: RandomForest = field(default_factory=RandomForest)

    def __post_init__(self):
        self._cfg_to_id = {c: i for i, c in enumerate(self.space)}

    def encode(self, feats: MatrixFeatures, dim: int) -> np.ndarray:
        return feats.vector(dim)

    def fit(self, samples):
        """samples: list of (MatrixFeatures, dim, best_config)."""
        X = np.stack([self.encode(f, d) for f, d, _ in samples])
        y = np.array([self._cfg_to_id[c] for _, _, c in samples])
        self.forest.fit(X, y, n_classes=len(self.space))
        return self

    def predict(self, feats: MatrixFeatures, dim: int) -> SpMMConfig:
        proba = self.forest.predict_proba(self.encode(feats, dim)[None])[0]
        # mask configs whose F exceeds this dim's tile range
        valid = np.array([c.F <= max(1, -(-dim // 128)) for c in self.space])
        proba = np.where(valid, proba, -1.0)
        chosen = self.space[int(proba.argmax())]
        if _obs_trace.trace_enabled():
            _obs_decisions.record_decision(
                source="decider", dim=dim, chosen=chosen,
                scores=[(c, p) for c, p in zip(self.space, proba) if p >= 0],
                snapshot=feats.as_dict())
        return chosen

    def predict_for(self, csr: CSRMatrix, dim: int) -> SpMMConfig:
        return self.predict(extract_features(csr), dim)

    def save(self, path: str):
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "SpMMDecider":
        with open(path, "rb") as f:
            return pickle.load(f)
