"""ParamSpMM computing engine (paper Alg. 2) — pure-JAX implementation.

Same PCSR traversal as the Pallas kernel, expressed as gather + scatter-add
so it jit-compiles natively on any backend (CPU benchmarking, GNN training)
and is differentiable.  The Pallas kernel in ``repro.kernels.paramspmm`` is
the TPU artifact; both are validated against ``ref.py``.

``make_spmm_fn`` builds the differentiable operator: the backward SpMM
``dB = Aᵀ·dC`` runs a second PCSR built for ``Aᵀ`` — GNN training performs
forward and backward SpMM exactly as the paper's PyTorch extension does.

``make_gat_message_fn`` builds the attention-GNN operator over the same
PCSR: SDDMM → LeakyReLU → edge softmax → SpMM, single- or multi-head.  On
the Pallas backend both the forward (fused softmax epilogue) and the
dedicated backward (transpose-PCSR SpMMs) run entirely in kernels; see the
function docstring and docs/OPERATORS.md for the exact pipelines.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .pcsr import PCSR, SpMMConfig, build_pcsr
from .sparse import CSRMatrix


@functools.partial(jax.jit, static_argnames=("V", "R", "K", "n_blocks", "n_rows"))
def _engine(colidx, lrow, trow, vals, B, *, V, R, K, n_blocks, n_rows):
    """Scatter-add evaluation of the packed PCSR chunks."""
    ck = colidx.shape[0]
    gathered = jnp.take(B, colidx, axis=0)                    # (C·K, dim)
    base = jnp.repeat(trow, K).astype(jnp.int32) * R + lrow * V
    valsf = jnp.swapaxes(vals, 1, 2).reshape(ck, V).astype(B.dtype)
    out = jnp.zeros((n_blocks * R, B.shape[1]), B.dtype)
    for v in range(V):                                        # V ≤ 2, unrolled
        out = out.at[base + v].add(valsf[:, v][:, None] * gathered)
    return out[:n_rows]


def engine_spmm(pcsr: PCSR, B):
    """C = A·B on the jit'd JAX engine."""
    arrs = pcsr.to_jax()
    cfg = pcsr.config
    return _engine(arrs["colidx"], arrs["lrow"], arrs["trow"], arrs["vals"],
                   jnp.asarray(B), V=cfg.V, R=cfg.R, K=pcsr.K,
                   n_blocks=pcsr.n_blocks, n_rows=pcsr.n_rows)


@functools.partial(jax.jit, static_argnames=("V", "R", "K"))
def _engine_sddmm(colidx, lrow, trow, vals, Q, K_mat, *, V, R, K):
    """Gather/dot evaluation of per-slot SDDMM scores (C, V, K)."""
    ck = colidx.shape[0]
    C = ck // K
    gathered = jnp.take(K_mat, colidx, axis=0)                # (C·K, d)
    base = jnp.repeat(trow, K).astype(jnp.int32) * R + lrow * V
    scores = []
    for v in range(V):                                        # V ≤ 2, unrolled
        # rows past n_rows (block padding) read as zero → score 0
        qrow = jnp.take(Q, base + v, axis=0, mode="fill", fill_value=0)
        scores.append(jnp.sum(qrow * gathered, axis=1))
    e = jnp.stack(scores, axis=1)                             # (C·K, V)
    e = jnp.swapaxes(e.reshape(C, K, V), 1, 2)                # (C, V, K)
    return jnp.where(vals != 0, e, 0.0)


def engine_sddmm(pcsr: PCSR, Q, K_mat):
    """E = (A≠0) ⊙ (Q·Kᵀ) in PCSR slot layout, on the jit'd JAX engine."""
    arrs = pcsr.to_jax()
    cfg = pcsr.config
    return _engine_sddmm(arrs["colidx"], arrs["lrow"], arrs["trow"],
                         arrs["vals"], jnp.asarray(Q), jnp.asarray(K_mat),
                         V=cfg.V, R=cfg.R, K=pcsr.K)


def _slot_rows(lrow, trow, *, V, R, K):
    """Destination row of every slot, in (C, V, K) layout."""
    C = trow.shape[0]
    base = trow[:, None, None].astype(jnp.int32) * R \
        + lrow.reshape(C, 1, K) * V
    return base + jnp.arange(V, dtype=jnp.int32)[None, :, None]


def edge_softmax(scores, mask, rows, n_segments: int):
    """Numerically-stable softmax over each destination row's edge set.

    scores/mask/rows all (C, V, K); padding slots (mask False) get weight 0
    and never contribute to their row's max or normalizer.
    """
    flat_r = rows.reshape(-1)
    neg = jnp.where(mask, scores, -jnp.inf).reshape(-1)
    rowmax = jax.ops.segment_max(neg, flat_r, num_segments=n_segments)
    rowmax = jnp.where(jnp.isfinite(rowmax), rowmax, 0.0)     # empty rows
    ex = jnp.exp(neg - rowmax[flat_r])
    ex = jnp.where(mask.reshape(-1), ex, 0.0)
    denom = jax.ops.segment_sum(ex, flat_r, num_segments=n_segments)
    alpha = ex / jnp.maximum(denom[flat_r], 1e-30)
    return alpha.reshape(scores.shape)


def attend_scores(scores, mask, rows, n_segments: int, *,
                  dim_k: int, slope: float = 0.2):
    """The GAT attention step shared by every backend: scale raw SDDMM
    scores by 1/√d_k, LeakyReLU(slope), softmax over each destination
    row's edge set.  Single source of truth — the single-device message
    fn and the distributed per-shard branches (``repro.dist.spmm``) must
    stay semantically identical."""
    scaled = scores / jnp.sqrt(jnp.asarray(dim_k, scores.dtype))
    scaled = jax.nn.leaky_relu(scaled, negative_slope=slope)
    return edge_softmax(scaled, mask, rows, n_segments)


def make_gat_message_fn(pcsr: PCSR, pcsr_t: Optional[PCSR] = None, *,
                        backend: str = "engine",
                        interpret: bool = True, slope: float = 0.2):
    """Differentiable fused GAT message ``f(Q, K, Vf) -> (n_rows, d)``:
    SDDMM → LeakyReLU → softmax-over-edges → SpMM, all over one PCSR.

    Scores are scaled by 1/√d_k (dot-product attention) then passed through
    LeakyReLU(slope) as in GAT.  Multi-head: rank-3 ``(H, n, d)`` operands
    return ``(H, n_rows, d)`` — the Pallas backend batches every head
    through one head-tiled kernel call (a single compilation), the engine
    backend vmaps its jitted path.

    Backends:

    * ``"engine"`` — the pure-JAX path, returned as-is: natively
      differentiable, no ``custom_vjp`` required.
    * ``"pallas"`` — the **two-kernel forward**: the fused SDDMM→softmax
      kernel (``sddmm_softmax_stats``: row max/normalizer accumulated in
      the kernel epilogue while the score block is VMEM resident) hands
      (logits, rowmax, rowsum) straight to the SpMM kernel's softmax
      *prologue* (``paramspmm_with_vals(stats=...)``), which rebuilds
      α = exp(logit − max)/Σ in-register while loading vals — NO
      interstitial elementwise pass and α is never materialized in HBM.

      The backward is flash-style recompute: residuals are only the raw
      logits + the two tile-aligned row-stat arrays
      ((n_blocks·SUBLANES, LANES), the kernel's native layout) — the
      (C, V, K) α residual is dropped and α is recomputed from the stats
      where the vjp needs it.  The pipeline is dedicated all-Pallas — no engine
      fallback:

        α   = exp(logits − rowmax)/rowsum       (recompute, no residual)
        dα  = SDDMM(pcsr, dOut, Vf)            (dα_ij = dOut_i·Vf_j)
        dx  = α ⊙ (dα − Σ_row α·dα)            (softmax vjp, per-slot)
        de  = dx · scale · LeakyReLU'(x)        (activation chain)
        dQ  = SpMM(pcsr,  de, K)               (row-gather of keys)
        dK  = SpMM(pcsrᵀ, deᵀ, Q)              (transpose-PCSR SpMM)
        dVf = SpMM(pcsrᵀ, αᵀ, dOut)            (transpose-PCSR SpMM)

      The transpose PCSR is built once (``core.pcsr.transpose_pcsr``) when not
      supplied — pass ``ParamSpMMOperator.pcsr_t`` to share the cached one
      — and slot tensors move between the two layouts through a
      precomputed ``slot_transfer_map`` gather/scatter.
    """
    arrs = pcsr.to_jax()
    cfg = pcsr.config
    V, R, K, n_blocks = cfg.V, cfg.R, pcsr.K, pcsr.n_blocks
    n_rows = pcsr.n_rows
    mask = arrs["vals"] != 0
    rows = _slot_rows(arrs["lrow"], arrs["trow"], V=V, R=R, K=K)

    def _attend(scores, Q):
        return attend_scores(scores, mask, rows, n_blocks * R,
                             dim_k=Q.shape[1], slope=slope)

    def engine_path(Q, K_mat, Vf):
        scores = _engine_sddmm(arrs["colidx"], arrs["lrow"], arrs["trow"],
                               arrs["vals"], Q, K_mat, V=V, R=R, K=K)
        alpha = _attend(scores, Q)
        return _engine(arrs["colidx"], arrs["lrow"], arrs["trow"], alpha,
                       Vf, V=V, R=R, K=K, n_blocks=n_blocks, n_rows=n_rows)

    def engine_fn(Q, K_mat, Vf):
        if jnp.ndim(Q) == 3:
            return jax.vmap(engine_path)(Q, K_mat, Vf)
        return engine_path(Q, K_mat, Vf)

    if backend != "pallas":
        return engine_fn            # natively differentiable, no vjp needed

    from repro.kernels.paramspmm.ops import paramspmm_with_vals
    from repro.kernels.sddmm.ops import (normalize_from_stats,
                                         sddmm as _sddmm_call,
                                         sddmm_softmax_stats, unpack_stats)

    from .pcsr import slot_transfer_map, transpose_pcsr
    if pcsr_t is None:
        pcsr_t = transpose_pcsr(pcsr)
    f_idx, t_idx = slot_transfer_map(pcsr, pcsr_t)
    n_tslots = pcsr_t.num_chunks * cfg.V * pcsr_t.K
    flat_rows = rows.reshape(-1)

    def _to_transpose(x):
        """Re-lay a (..., C, V, K) slot tensor onto the Aᵀ PCSR's slots."""
        lead = x.shape[:-3]
        tf = jnp.zeros(lead + (n_tslots,), x.dtype)
        tf = tf.at[..., t_idx].set(x.reshape(lead + (-1,))[..., f_idx])
        return tf.reshape(lead + (pcsr_t.num_chunks, cfg.V, pcsr_t.K))

    def _rowsum(x):
        """Per-slot broadcast of Σ over each destination row's slots."""
        s = jax.ops.segment_sum(x.reshape(-1), flat_rows,
                                num_segments=n_blocks * R)
        return s[flat_rows].reshape(x.shape)

    def _alpha_1h(logits, rowmax, rowsum):
        """Flash-style α recompute from the stats residuals (one head) —
        the single normalize implementation shared with sddmm/ops, so the
        masked-slot/empty-row guard convention cannot drift."""
        return normalize_from_stats(logits, rowmax, rowsum, arrs["lrow"],
                                    arrs["trow"], R=R, V=V, K=K)

    def _alpha(logits, rowmax, rowsum):
        rm = unpack_stats(rowmax, R)       # tile-aligned → dense (·, R)
        rs = unpack_stats(rowsum, R)
        if logits.ndim == 4:                            # (H, C, V, K)
            H = logits.shape[0]
            return jax.vmap(_alpha_1h)(logits, rm.reshape(H, -1, R),
                                       rs.reshape(H, -1, R))
        return _alpha_1h(logits, rm, rs)

    def fwd_path(Q, K_mat, Vf):
        logits, rowmax, rowsum = sddmm_softmax_stats(
            pcsr, Q, K_mat, slope=slope, interpret=interpret)
        out = paramspmm_with_vals(pcsr, logits, Vf, stats=(rowmax, rowsum),
                                  interpret=interpret)
        return out, (Q, K_mat, Vf, logits, rowmax, rowsum)

    @jax.custom_vjp
    def f(Q, K_mat, Vf):
        return fwd_path(Q, K_mat, Vf)[0]

    def f_fwd(Q, K_mat, Vf):
        return fwd_path(Q, K_mat, Vf)

    def f_bwd(res, dOut):
        Q, K_mat, Vf, logits, rowmax, rowsum = res
        alpha = _alpha(logits, rowmax, rowsum)          # recompute, cheap
        scale = 1.0 / jnp.sqrt(jnp.asarray(Q.shape[-1], dOut.dtype))
        dalpha = _sddmm_call(pcsr, dOut, Vf, interpret=interpret)
        rsum = (jax.vmap(_rowsum) if alpha.ndim == 4 else _rowsum)
        dx = alpha * (dalpha - rsum(alpha * dalpha))       # softmax vjp
        # LeakyReLU' from the saved logits: LeakyReLU preserves sign, so
        # sign(logits) = sign(pre-activation); masked slots (logit −inf)
        # have dx = 0, so the slope branch they fall into is inert.
        de = dx * scale * jnp.where(logits >= 0, 1.0, slope)
        dQ = paramspmm_with_vals(pcsr, de, K_mat, interpret=interpret)
        dK = paramspmm_with_vals(pcsr_t, _to_transpose(de), Q,
                                 interpret=interpret)
        dVf = paramspmm_with_vals(pcsr_t, _to_transpose(alpha), dOut,
                                  interpret=interpret)
        return dQ, dK, dVf

    f.defvjp(f_fwd, f_bwd)
    return f


def make_spmm_fn(pcsr: PCSR, pcsr_t: Optional[PCSR] = None, *,
                 backend: str = "engine", interpret: bool = True):
    """Build a differentiable ``f(B) = A·B`` closed over PCSR arrays.

    backend: "engine" (pure JAX, fast on CPU) or "pallas" (TPU kernel,
    interpret-mode on CPU).  The VJP uses the transpose PCSR when given,
    otherwise gradients flow through the engine's gather/scatter directly.
    """
    if backend == "pallas":
        from repro.kernels.paramspmm.ops import paramspmm as _fwd_call
        fwd = lambda B: _fwd_call(pcsr, B, interpret=interpret)
    else:
        fwd = lambda B: engine_spmm(pcsr, B)

    if pcsr_t is None:
        return fwd

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import paramspmm as _bwd_call
        bwd = lambda dC: _bwd_call(pcsr_t, dC, interpret=interpret)
    else:
        bwd = lambda dC: engine_spmm(pcsr_t, dC)

    @jax.custom_vjp
    def f(B):
        return fwd(B)

    def f_fwd(B):
        return fwd(B), None

    def f_bwd(_, dC):
        return (bwd(dC),)

    f.defvjp(f_fwd, f_bwd)
    return f


def apply_epilogue(out, scale=None, bias=None, activation: str = "none",
                   slope: float = 0.2, residual=None):
    """The SpMM epilogue semantics, in plain JAX:
    ``act(scale[:, None] ⊙ out + bias[None, :] + residual)``.  Single
    source of truth for what the Pallas kernel's fused epilogue computes
    — the engine backend and the per-shard distributed branches run this
    (XLA fuses it into the surrounding program), the Pallas kernel
    applies the same ops to the VMEM-resident output block before
    write-back.  ``residual`` is a dense (n, d) addend (GIN's ``(1+ε)h``
    term)."""
    if scale is not None:
        out = out * scale[:, None]
    if bias is not None:
        out = out + bias[None, :]
    if residual is not None:
        out = out + residual
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation == "leaky_relu":
        out = jax.nn.leaky_relu(out, negative_slope=slope)
    elif activation != "none":
        raise ValueError(f"unknown epilogue activation {activation!r}")
    return out


def epilogue_grad(out, dOut, activation: str = "none", slope: float = 0.2):
    """d(pre-activation) of the fused epilogue from its *output*: both
    relu and leaky_relu preserve sign, so act' is recoverable from ``out``
    alone.  The one backward for ``apply_epilogue``'s activations — the
    single-device and distributed fused custom_vjps both call this, so
    the derivative (and the slope constant) cannot drift between them."""
    if activation == "relu":
        return jnp.where(out > 0, dOut, 0.0)
    if activation == "leaky_relu":
        return jnp.where(out >= 0, dOut, slope * dOut)
    if activation != "none":
        raise ValueError(f"unknown epilogue activation {activation!r}")
    return dOut


def engine_spmm_fused(pcsr: PCSR, B, *, scale=None, bias=None,
                      residual=None, activation: str = "none"):
    """act(scale ⊙ (A·B) + bias + residual) on the jit'd JAX engine — the
    reference semantics of the fused-epilogue kernel, natively
    differentiable."""
    return apply_epilogue(engine_spmm(pcsr, B), scale, bias, activation,
                          residual=residual)


def make_fused_spmm_fn(pcsr: PCSR, pcsr_t: Optional[PCSR] = None, *,
                       backend: str = "engine", interpret: bool = True):
    """Build the epilogue-fused aggregation closure
    ``fused(B, scale=None, bias=None, activation="none", residual=None)
    -> (n, d)`` computing ``act(scale ⊙ (A·B) + bias + residual)`` — one
    kernel on the Pallas backend (scale/bias/residual/activation applied
    to the VMEM-resident output block on its last visit) instead of
    kernel + 2–3 XLA elementwise passes over the (n, d) output.  The
    dense ``residual`` operand is what lets GIN's ``(1+ε)h + A·h``
    aggregation run as ONE kernel.

    Differentiable in ``B``, ``bias``, and ``residual`` (``scale`` is
    graph data — degree norms — and is treated as a constant): with
    ``pcsr_t`` both backends run a ``custom_vjp`` whose backward is

        dpre  = dOut ⊙ act'(out)          (act' recovered from out: both
                                           relu and leaky_relu preserve sign)
        dbias = Σ_rows dpre
        dresidual = dpre                   (the add is linear)
        dB    = SpMM(pcsrᵀ, scale ⊙ dpre)  (transpose-PCSR SpMM)

    — the same transpose path the plain ``make_spmm_fn`` takes, so fusing
    never swaps the optimized backward for a generic scatter transpose.
    Without ``pcsr_t`` the engine path falls back to native autodiff; the
    Pallas path requires it for gradients.
    """
    if backend == "pallas":
        from repro.kernels.paramspmm.ops import paramspmm

        def fwd_call(B, scale, bias, residual, activation):
            return paramspmm(pcsr, B, scale=scale, bias=bias,
                             residual=residual, activation=activation,
                             interpret=interpret)

        def bwd_call(dC):
            return paramspmm(pcsr_t, dC, interpret=interpret)
    else:
        def fwd_call(B, scale, bias, residual, activation):
            return engine_spmm_fused(pcsr, B, scale=scale, bias=bias,
                                     residual=residual,
                                     activation=activation)

        def bwd_call(dC):
            return engine_spmm(pcsr_t, dC)

    if backend != "pallas" and pcsr_t is None:
        def fused(B, scale=None, bias=None, activation: str = "none",
                  residual=None):
            return fwd_call(B, scale, bias, residual,
                            activation)  # native autodiff
        return fused

    vjps: dict = {}                # one custom_vjp per activation

    def _vjp(activation: str):
        # scale/bias/residual enter as primals (None stays a None pytree
        # leaf) so a traced scale never leaks into the vjp closure;
        # scale's cotangent is zero — degree norms are graph data, not a
        # trained parameter.
        @jax.custom_vjp
        def f(B, scale, bias, residual):
            return fwd_call(B, scale, bias, residual, activation)

        def f_fwd(B, scale, bias, residual):
            out = fwd_call(B, scale, bias, residual, activation)
            return out, (out, scale, bias, residual is not None)

        def f_bwd(res, dOut):
            out, scale, bias, has_resid = res
            if pcsr_t is None:
                raise ValueError("fused SpMM backward needs the transpose "
                                 "PCSR — build the operator with "
                                 "build_transpose=True")
            dpre = epilogue_grad(out, dOut, activation)
            dbias = None if bias is None else dpre.sum(axis=0)
            dresid = dpre if has_resid else None
            dcb = dpre if scale is None else dpre * scale[:, None]
            dB = bwd_call(dcb)
            dscale = None if scale is None else jnp.zeros_like(scale)
            return dB, dscale, dbias, dresid

        f.defvjp(f_fwd, f_bwd)
        return f

    def fused(B, scale=None, bias=None, activation: str = "none",
              residual=None):
        if activation not in vjps:
            vjps[activation] = _vjp(activation)
        return vjps[activation](
            B, None if scale is None else jnp.asarray(scale),
            None if bias is None else jnp.asarray(bias),
            None if residual is None else jnp.asarray(residual))
    return fused


class ParamSpMMOperator:
    """User-facing operator: holds forward + transpose PCSR for one sparse
    matrix under one ⟨W,F,V,S⟩ configuration.  ``op(B)`` is the plain
    SpMM; ``op.fused(B, scale=, bias=, activation=, residual=)`` the
    epilogue-fused aggregation (one kernel per GCN — or, via the
    residual addend, GIN — layer on the Pallas backend)."""

    def __init__(self, csr: CSRMatrix, config: SpMMConfig, *,
                 backend: str = "engine", interpret: bool = True,
                 build_transpose: bool = True):
        self.csr = csr
        self.config = config
        self.backend = backend
        self.pcsr = build_pcsr(csr.indptr, csr.indices, csr.data,
                               csr.n_rows, csr.n_cols, config)
        self.pcsr_t = None
        if build_transpose:
            t = csr.transpose()
            self.pcsr_t = build_pcsr(t.indptr, t.indices, t.data,
                                     t.n_rows, t.n_cols, config)
        self._fn = make_spmm_fn(self.pcsr, self.pcsr_t,
                                backend=backend, interpret=interpret)
        self.fused = make_fused_spmm_fn(self.pcsr, self.pcsr_t,
                                        backend=backend, interpret=interpret)

    def __call__(self, B):
        return self._fn(B)
