"""ParamSpMM computing engine (paper Alg. 2) — pure-JAX implementation.

Same PCSR traversal as the Pallas kernel, expressed as gather + scatter-add
so it jit-compiles natively on any backend (CPU benchmarking, GNN training)
and is differentiable.  The Pallas kernel in ``repro.kernels.paramspmm`` is
the TPU artifact; both are validated against ``ref.py``.

``make_spmm_fn`` builds the differentiable operator: the backward SpMM
``dB = Aᵀ·dC`` runs a second PCSR built for ``Aᵀ`` — GNN training performs
forward and backward SpMM exactly as the paper's PyTorch extension does.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .pcsr import PCSR, SpMMConfig, build_pcsr
from .sparse import CSRMatrix


@functools.partial(jax.jit, static_argnames=("V", "R", "K", "n_blocks", "n_rows"))
def _engine(colidx, lrow, trow, vals, B, *, V, R, K, n_blocks, n_rows):
    """Scatter-add evaluation of the packed PCSR chunks."""
    ck = colidx.shape[0]
    gathered = jnp.take(B, colidx, axis=0)                    # (C·K, dim)
    base = jnp.repeat(trow, K).astype(jnp.int32) * R + lrow * V
    valsf = jnp.swapaxes(vals, 1, 2).reshape(ck, V).astype(B.dtype)
    out = jnp.zeros((n_blocks * R, B.shape[1]), B.dtype)
    for v in range(V):                                        # V ≤ 2, unrolled
        out = out.at[base + v].add(valsf[:, v][:, None] * gathered)
    return out[:n_rows]


def engine_spmm(pcsr: PCSR, B):
    """C = A·B on the jit'd JAX engine."""
    arrs = pcsr.to_jax()
    cfg = pcsr.config
    return _engine(arrs["colidx"], arrs["lrow"], arrs["trow"], arrs["vals"],
                   jnp.asarray(B), V=cfg.V, R=cfg.R, K=pcsr.K,
                   n_blocks=pcsr.n_blocks, n_rows=pcsr.n_rows)


def make_spmm_fn(pcsr: PCSR, pcsr_t: Optional[PCSR] = None, *,
                 backend: str = "engine", interpret: bool = True):
    """Build a differentiable ``f(B) = A·B`` closed over PCSR arrays.

    backend: "engine" (pure JAX, fast on CPU) or "pallas" (TPU kernel,
    interpret-mode on CPU).  The VJP uses the transpose PCSR when given,
    otherwise gradients flow through the engine's gather/scatter directly.
    """
    if backend == "pallas":
        from repro.kernels.paramspmm.ops import paramspmm as _fwd_call
        fwd = lambda B: _fwd_call(pcsr, B, interpret=interpret)
    else:
        fwd = lambda B: engine_spmm(pcsr, B)

    if pcsr_t is None:
        return fwd

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import paramspmm as _bwd_call
        bwd = lambda dC: _bwd_call(pcsr_t, dC, interpret=interpret)
    else:
        bwd = lambda dC: engine_spmm(pcsr_t, dC)

    @jax.custom_vjp
    def f(B):
        return fwd(B)

    def f_fwd(B):
        return fwd(B), None

    def f_bwd(_, dC):
        return (bwd(dC),)

    f.defvjp(f_fwd, f_bwd)
    return f


class ParamSpMMOperator:
    """User-facing operator: holds forward + transpose PCSR for one sparse
    matrix under one ⟨W,F,V,S⟩ configuration."""

    def __init__(self, csr: CSRMatrix, config: SpMMConfig, *,
                 backend: str = "engine", interpret: bool = True,
                 build_transpose: bool = True):
        self.csr = csr
        self.config = config
        self.backend = backend
        self.pcsr = build_pcsr(csr.indptr, csr.indices, csr.data,
                               csr.n_rows, csr.n_cols, config)
        self.pcsr_t = None
        if build_transpose:
            t = csr.transpose()
            self.pcsr_t = build_pcsr(t.indptr, t.indices, t.data,
                                     t.n_rows, t.n_cols, config)
        self._fn = make_spmm_fn(self.pcsr, self.pcsr_t,
                                backend=backend, interpret=interpret)

    def __call__(self, B):
        return self._fn(B)
