"""Distributed graph-operator subsystem: partitioned PCSR + shard_map
SpMM/GAT with per-partition adaptive ⟨W,F,V,S⟩ configurations.

Layers (see docs/ARCHITECTURE.md §Distributed execution):

* ``partition`` — 1D row partitioning (contiguous / balanced-nnz) into
  per-shard local CSRs with compact halo column maps;
* ``halo``      — compacted halo feature exchange (+ gradient
  scatter-back) over the ``("parts",)`` device mesh;
* ``spmm``      — ``DistGraph`` / ``dist_spmm`` / ``dist_gat_message``:
  one SPMD ``shard_map`` program whose per-shard branches run the
  existing engine/Pallas kernels under shard-specific configs.
"""
from .halo import HaloSpec, build_halo, halo_exchange, halo_scatter_back
from .partition import (RowPartition, Shard, partition_bounds,
                        partition_csr, unpartition_rows)
from .spmm import DistGraph, dist_gat_message, dist_spmm, pack_shards

__all__ = [
    "RowPartition", "Shard", "partition_bounds", "partition_csr",
    "unpartition_rows",
    "HaloSpec", "build_halo", "halo_exchange", "halo_scatter_back",
    "DistGraph", "dist_spmm", "dist_gat_message", "pack_shards",
]
