"""Distributed graph-operator subsystem: partitioned PCSR + shard_map
SpMM/GAT with per-partition adaptive ⟨W,F,V,S⟩ configurations.

Layers (see docs/DISTRIBUTED.md and docs/ARCHITECTURE.md §Distributed
execution):

* ``partition`` — 1D row partitioning (contiguous / balanced-nnz) into
  per-shard local CSRs with compact halo column maps, plus the
  local/halo edge split the overlap path executes;
* ``halo``      — compacted halo feature exchange (+ gradient
  scatter-back) over the ``("parts",)`` device mesh;
* ``packing``   — mesh plumbing: the shared ``shard_map`` wrapper and
  the per-shard (head-tiled) covered steering packs;
* ``spmm``      — ``DistGraph`` / ``dist_spmm``: one SPMD ``shard_map``
  program whose per-shard branches run the existing engine/Pallas
  kernels under shard-specific configs, with optional halo/compute
  overlap (``DistGraph(overlap=True)``);
* ``gat``       — ``dist_gat_message``: the multi-head distributed GAT
  message — two Pallas kernels per shard forward, all-Pallas
  flash-recompute backward with halo gradient scatter-back.
"""
from .halo import HaloSpec, build_halo, halo_exchange, halo_scatter_back
from .packing import PackedShards, pack_shards
from .partition import (RowPartition, Shard, partition_bounds,
                        partition_csr, split_local_halo, unpartition_rows)
from .spmm import DistGraph, dist_gat_message, dist_spmm

__all__ = [
    "RowPartition", "Shard", "partition_bounds", "partition_csr",
    "split_local_halo", "unpartition_rows",
    "HaloSpec", "build_halo", "halo_exchange", "halo_scatter_back",
    "DistGraph", "dist_spmm", "dist_gat_message",
    "PackedShards", "pack_shards",
]
