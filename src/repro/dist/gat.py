"""Distributed GAT message: multi-head shards + an all-Pallas backward.

The single-device GAT hot path (``core.engine.make_gat_message_fn``) is a
two-kernel forward — fused SDDMM→softmax *stats* kernel feeding the
ParamSpMM softmax *prologue* — with a flash-style recompute backward
whose heavy ops are three more kernels over the forward and transpose
PCSRs.  This module runs exactly that pipeline **per shard inside one
SPMD ``shard_map`` program**, multi-head:

* **forward** — K/Vf are halo-exchanged jointly (one ``all_gather``
  serves every head of both operands: heads travel merged as
  ``(rows, H·d)`` columns), then each shard's branch splits the heads
  and batches them through its OWN head-tiled steering arrays
  (``PCSR.steering(H, covered=True)``, packed per partition by
  ``packing.pack_shards(H=)``) — exactly two Pallas kernels per shard,
  α never in HBM, one compilation for the whole head batch.
* **backward** — a ``custom_vjp`` (Pallas backend): residuals are the
  primals plus the per-shard raw logits and the tile-aligned
  ``(H·n_blocks·SUBLANES, LANES)`` row stats (flash-style — no α
  residual); the backward shard_map program
  re-exchanges the K/Vf halo (recompute over memory), recomputes α from
  the stats, runs dα-SDDMM, dQ-SpMM and the transpose-PCSR dK/dVf SpMMs
  as Pallas kernels, and scatters the halo blocks of dK/dVf back to
  their owner shards through ``halo_scatter_back`` — no engine fallback
  anywhere (enforced by test).

Row partitioning keeps every destination row's full edge set on one
shard, so the softmax — forward stats and backward vjp alike — never
communicates; only the operand halo exchange and the gradient
scatter-back cross the mesh.

The engine backend keeps the natively-differentiable pure-JAX pipeline
(vmapped over heads); its halo gradients flow back through the autodiff
transpose of ``all_gather`` (a ``psum_scatter``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (_engine, _engine_sddmm, _slot_rows,
                               attend_scores)
from repro.core.pcsr import (LANES, SUBLANES, slot_transfer_map,
                             transpose_pcsr)
from repro.obs import trace as _obs_trace

from .halo import halo_exchange, halo_scatter_back
from .packing import AXIS, PackedShards, pack_shards, shard_map_2d

# transfer-map padding: out-of-bounds target slots are dropped by the
# scatter (mode="drop"), so padded map entries can never alias real slots
T_SENTINEL = np.int32(2**31 - 1)


def head_split(x2, H: int):
    """(n, H·d) merged mesh layout → (H, n, d) head stack."""
    n = x2.shape[0]
    return x2.reshape(n, H, -1).transpose(1, 0, 2)


def head_merge(x3):
    """(H, n, d) head stack → (n, H·d) merged mesh layout."""
    H, n, d = x3.shape
    return x3.transpose(1, 0, 2).reshape(n, H * d)


def _uncov(a, per: int, H: int, Cc: int, C: int):
    """Recover the uncovered H-tiled array from a covered one: per head,
    the real chunks are the ``[:C·per]`` prefix of that head's
    ``Cc·per``-long segment (coverage chunks pack last)."""
    return a[:H * Cc * per].reshape(H, Cc * per)[:, :C * per].reshape(-1)


@dataclass
class GatShardPack:
    """Head-tiled covered steering packs for the distributed GAT.

    ``fwd`` packs every shard's ``steering(H, covered=True)`` arrays;
    ``logits_pad``/``stats_pad`` are the uniform residual widths the
    forward branches pad their (per-shard-sized) logits and row stats to
    so they cross the ``shard_map`` boundary as stacked ``(P, ·)``
    tensors.  The backward side — transpose PCSRs packed the same way
    plus the per-edge slot transfer maps — is built lazily on the first
    backward trace (forward-only use never pays for it)."""

    H: int
    fwd: PackedShards
    logits_pad: int              # max over shards of H·C·V·K
    stats_pad: int               # max over shards of H·nb·SUBLANES·LANES
    bwd: Optional[PackedShards] = None    # transpose PCSRs (lazy)
    f_idx: Optional[jnp.ndarray] = None   # (P, L) A-layout slot positions
    t_idx: Optional[jnp.ndarray] = None   # (P, L) Aᵀ-layout positions


def build_gat_pack(pcsrs, H: int,
                   fwd: Optional[PackedShards] = None) -> GatShardPack:
    """Pack the shards' head-tiled covered steering for one head count.
    Pass an existing H=1 pack as ``fwd`` to reuse it (the single-head
    covered arrays are identical — no second device-resident copy)."""
    with _obs_trace.span("gat.pack", H=H, n_parts=len(pcsrs),
                         reused=fwd is not None):
        return GatShardPack(
            H, fwd if fwd is not None else pack_shards(pcsrs, H=H),
            logits_pad=max(H * p.num_chunks * p.config.V * p.K
                           for p in pcsrs),
            stats_pad=max(H * p.n_blocks * SUBLANES * LANES for p in pcsrs))


def ensure_gat_bwd_pack(pack: GatShardPack) -> None:
    """Build the transpose-PCSR pack + slot transfer maps (idempotent)."""
    if pack.bwd is not None:
        return
    with _obs_trace.span("gat.bwd_pack", H=pack.H,
                         n_parts=len(pack.fwd.pcsrs)):
        _build_gat_bwd_pack(pack)


def _build_gat_bwd_pack(pack: GatShardPack) -> None:
    pts = [transpose_pcsr(p) for p in pack.fwd.pcsrs]
    maps = [slot_transfer_map(p, pt)
            for p, pt in zip(pack.fwd.pcsrs, pts)]
    P = len(pts)
    L = max([m[0].size for m in maps] + [1])
    f = np.zeros((P, L), np.int32)
    t = np.full((P, L), T_SENTINEL, np.int32)
    for i, (fi, ti) in enumerate(maps):
        f[i, :fi.size] = fi
        t[i, :ti.size] = ti
    pack.bwd = pack_shards(pts, H=pack.H)
    # built lazily on the first backward trace — keep the cached maps
    # concrete so later traces can reuse them (see packing.pack_shards)
    with jax.ensure_compile_time_eval():
        pack.f_idx, pack.t_idx = jnp.asarray(f), jnp.asarray(t)


# ------------------------------------------------------------ branches
def _engine_fwd_branch(pcsr, *, H: int, n_out: int, slope: float):
    """Pure-JAX per-shard branch: SDDMM → attend → SpMM, vmapped over
    heads.  Natively differentiable — the engine backend's whole
    distributed GAT program is plain autodiff."""
    cfg = pcsr.config
    C, K, V, R, nb = pcsr.num_chunks, pcsr.K, cfg.V, cfg.R, pcsr.n_blocks
    S, VS = C * K, C * V * K

    def branch(colidx, lrow, trow, init, fini, vals, q2, kx2, vfx2):
        ci, lr, tr = colidx[:S], lrow[:S], trow[:C]
        vv = vals[:VS].reshape(C, V, K)
        rows = _slot_rows(lr, tr, V=V, R=R, K=K)

        def one(qh, kh, vfh):
            scores = _engine_sddmm(ci, lr, tr, vv, qh, kh, V=V, R=R, K=K)
            alpha = attend_scores(scores, vv != 0, rows, nb * R,
                                  dim_k=qh.shape[1], slope=slope)
            return _engine(ci, lr, tr, alpha, vfh, V=V, R=R, K=K,
                           n_blocks=nb, n_rows=n_out)

        out = jax.vmap(one)(head_split(q2, H), head_split(kx2, H),
                            head_split(vfx2, H))
        return head_merge(out)
    return branch


def _pallas_fwd_branch(pcsr, *, H: int, n_out: int, slope: float,
                       interpret: bool, logits_pad: int, stats_pad: int):
    """The two-kernel fused forward with shard-static shapes: fused
    SDDMM→softmax-stats kernel, then the ParamSpMM softmax-prologue
    kernel over the covered head-tiled steering — α never materializes.
    Returns (out, logits, rowmax, rowsum), the latter three padded to
    the pack-uniform residual widths (flash-style backward inputs)."""
    from repro.kernels.paramspmm.kernel import paramspmm_kernel
    from repro.kernels.paramspmm.ops import _pad_chunk_vals, _pad_cols
    from repro.kernels.sddmm.kernel import sddmm_softmax_kernel
    from repro.kernels.sddmm.ops import _pad_q

    cfg = pcsr.config
    C, K, V, W = pcsr.num_chunks, pcsr.K, cfg.V, cfg.W
    nb, R, dblk = pcsr.n_blocks, cfg.R, cfg.dblk
    Cc = pcsr.covered_num_chunks

    def branch(colidx, lrow, trow, init, fini, vals, q2, kx2, vfx2):
        q, kx, vfx = (head_split(x, H) for x in (q2, kx2, vfx2))
        da, dv = q.shape[2], vfx.shape[2]
        # kernel 1: fused SDDMM → logits + online-softmax row stats, over
        # the uncovered head-tiled steering (stats of visited blocks only)
        Qp = _pad_q(q, nb * R, dblk).reshape(H * nb * R, -1)
        Kp, _ = _pad_cols(kx.reshape(-1, da), dblk)
        logits, rowmax, rowsum = sddmm_softmax_kernel(
            _uncov(colidx, K, H, Cc, C), _uncov(lrow, K, H, Cc, C),
            _uncov(trow, 1, H, Cc, C), _uncov(init, 1, H, Cc, C),
            vals[:H * Cc * V * K].reshape(H, Cc, V, K)[:, :C]
            .reshape(H * C, V, K),
            Qp, Kp, n_blocks=H * nb, W=W, V=V, K=K, dblk=dblk,
            scale=float(1.0 / np.sqrt(da)), slope=slope,
            interpret=interpret)
        # kernel 2: prologue SpMM — logits in, α rebuilt in-register;
        # coverage chunks carry −inf logits (exact α = 0)
        lg = _pad_chunk_vals(logits.reshape(H, C, V, K), Cc - C, -jnp.inf)
        Bp, _ = _pad_cols(vfx.reshape(-1, dv), dblk)
        out = paramspmm_kernel(
            colidx[:H * Cc * K], lrow[:H * Cc * K], trow[:H * Cc],
            init[:H * Cc], fini[:H * Cc], lg.reshape(H * Cc, V, K), Bp,
            n_blocks=H * nb, R=R, V=V, K=K, dblk=dblk,
            rowmax=rowmax, rowsum=rowsum, interpret=interpret)
        out = out[:, :dv].reshape(H, nb * R, dv)[:, :n_out]
        pad1 = lambda x, L: jnp.pad(x.reshape(-1), (0, L - x.size))[None, :]
        return (head_merge(out), pad1(logits, logits_pad),
                pad1(rowmax, stats_pad), pad1(rowsum, stats_pad))
    return branch


def _pallas_bwd_branch(pcsr, pcsr_t, *, H: int, n_out: int, slope: float,
                       interpret: bool):
    """The flash-style all-Pallas per-shard backward: α recomputed from
    the (logits, row-stats) residuals, then

        dα   = SDDMM(pcsr, dOut, Vf_ext)        [Pallas]
        dx   = α ⊙ (dα − Σ_row α·dα)            (softmax vjp, per slot)
        de   = dx · scale · LeakyReLU'(logits)
        dQ   = SpMM(pcsr,  de, K_ext)           [Pallas]
        dK   = SpMM(pcsrᵀ, deᵀ, Q)              [Pallas, transpose PCSR]
        dVf  = SpMM(pcsrᵀ, αᵀ, dOut)            [Pallas, transpose PCSR]

    — the same pipeline as the single-device vjp, with slot tensors moved
    onto the transpose layout through the packed transfer maps.  dK/dVf
    come back over the extended column space; the caller scatters their
    halo blocks home."""
    from repro.kernels.paramspmm.kernel import paramspmm_kernel
    from repro.kernels.paramspmm.ops import _pad_chunk_vals, _pad_cols
    from repro.kernels.sddmm.kernel import sddmm_kernel
    from repro.kernels.sddmm.ops import (_pad_q, normalize_from_stats,
                                         unpack_stats)

    cfg = pcsr.config
    C, K, V, W = pcsr.num_chunks, pcsr.K, cfg.V, cfg.W
    nb, R, dblk = pcsr.n_blocks, cfg.R, cfg.dblk
    Cc = pcsr.covered_num_chunks
    Ct, Kt, nbt = pcsr_t.num_chunks, pcsr_t.K, pcsr_t.n_blocks
    Ctc = pcsr_t.covered_num_chunks
    n_tslots = Ct * V * Kt
    ext = pcsr.n_cols                      # = pcsr_t.n_rows

    def spmm_heads(col, lr, tr, it, fi, vals4, B3, *, Cc_, Kc, nb_, n_r):
        """One head-tiled Pallas SpMM over covered steering; ``vals4``
        are the real chunks (coverage appended here, fill 0)."""
        d = B3.shape[2]
        v = _pad_chunk_vals(vals4, Cc_ - vals4.shape[1], 0.0)
        Bp, _ = _pad_cols(B3.reshape(-1, d), dblk)
        out = paramspmm_kernel(
            col[:H * Cc_ * Kc], lr[:H * Cc_ * Kc], tr[:H * Cc_],
            it[:H * Cc_], fi[:H * Cc_], v.reshape(H * Cc_, V, Kc), Bp,
            n_blocks=H * nb_, R=R, V=V, K=Kc, dblk=dblk,
            interpret=interpret)
        return out[:, :d].reshape(H, nb_ * R, d)[:, :n_r]

    def branch(fcol, flrow, ftrow, finit, ffini, fvals,
               tcol, tlrow, ttrow, tinit, tfini, tvals,
               fidx, tidx, do2, q2, kx2, vfx2, lgf, rmf, rsf):
        do, q, kx, vfx = (head_split(x, H) for x in (do2, q2, kx2, vfx2))
        da, dv = q.shape[2], do.shape[2]
        uvals = fvals[:H * Cc * V * K].reshape(H, Cc, V, K)[:, :C]
        # single-head slot→row map: head 0's prefix has zero offsets
        lr1, tr1 = flrow[:C * K], ftrow[:C]
        rows1 = _slot_rows(lr1, tr1, V=V, R=R, K=K).reshape(-1)
        # α recompute from the stats residuals (no α residual saved);
        # stats travel flat in the kernels' tile-aligned layout
        logits = lgf[:H * C * V * K].reshape(H, C, V, K)
        untile = lambda x: unpack_stats(
            x[:H * nb * SUBLANES * LANES].reshape(H * nb * SUBLANES, LANES),
            R).reshape(H, nb, R)
        rowmax = untile(rmf)
        rowsum = untile(rsf)
        alpha = jax.vmap(lambda lg, rm, rs: normalize_from_stats(
            lg, rm, rs, lr1, tr1, R=R, V=V, K=K))(logits, rowmax, rowsum)
        # dα — raw SDDMM kernel over the uncovered head-tiled steering
        Qp = _pad_q(do, nb * R, dblk).reshape(H * nb * R, -1)
        Kp, _ = _pad_cols(vfx.reshape(-1, dv), dblk)
        scores = sddmm_kernel(
            _uncov(fcol, K, H, Cc, C), _uncov(flrow, K, H, Cc, C),
            _uncov(ftrow, 1, H, Cc, C), Qp, Kp,
            W=W, V=V, K=K, dblk=dblk, interpret=interpret)
        dalpha = jnp.where(uvals.reshape(H * C, V, K) != 0, scores,
                           0.0).reshape(H, C, V, K)

        def rsum(x):
            s = jax.ops.segment_sum(x.reshape(-1), rows1,
                                    num_segments=nb * R)
            return s[rows1].reshape(x.shape)

        dx = alpha * (dalpha - jax.vmap(rsum)(alpha * dalpha))
        # LeakyReLU' from the logits (sign-preserving); masked slots have
        # logit −inf but dx = 0, so the slope branch they take is inert
        de = dx * float(1.0 / np.sqrt(da)) * jnp.where(logits >= 0,
                                                       1.0, slope)
        dQ = spmm_heads(fcol, flrow, ftrow, finit, ffini, de, kx,
                        Cc_=Cc, Kc=K, nb_=nb, n_r=n_out)

        def to_t(x):
            """Re-lay (H, C, V, K) slots onto the Aᵀ PCSR's slot tensor
            through the packed transfer maps (padded entries drop)."""
            buf = jnp.zeros((H, n_tslots), x.dtype)
            buf = buf.at[:, tidx].set(x.reshape(H, -1)[:, fidx],
                                      mode="drop")
            return buf.reshape(H, Ct, V, Kt)

        dK = spmm_heads(tcol, tlrow, ttrow, tinit, tfini, to_t(de), q,
                        Cc_=Ctc, Kc=Kt, nb_=nbt, n_r=ext)
        dVf = spmm_heads(tcol, tlrow, ttrow, tinit, tfini, to_t(alpha),
                         do, Cc_=Ctc, Kc=Kt, nb_=nbt, n_r=ext)
        return head_merge(dQ), head_merge(dK), head_merge(dVf)
    return branch


# ------------------------------------------------------------- builder
def build_dist_gat(g, *, slope: float, H: int):
    """Build the distributed (multi-head) GAT message closure for one
    DistGraph: ``f(Q, K, Vf) -> (H, n, d)`` over ``(H, n, d)`` stacks in
    the merged mesh layout handled by ``DistGraph.gat_message``.

    Engine backend → one natively-differentiable SPMD program.  Pallas
    backend → ``custom_vjp``: two kernels per shard forward, all-Pallas
    flash-recompute backward with halo gradient scatter-back."""
    rows_pad = g.part.rows_pad
    mesh = g.mesh

    def exchange(k2, vf2, sidx, hsrc):
        """Joint K/Vf halo exchange: one all_gather serves both operands
        of the shard's SDDMM + SpMM, every head included."""
        dk = k2.shape[1]
        halo = halo_exchange(jnp.concatenate([k2, vf2], axis=1),
                             sidx, hsrc, axis_name=AXIS)
        return (jnp.concatenate([k2, halo[:, :dk]], axis=0),
                jnp.concatenate([vf2, halo[:, dk:]], axis=0))

    if g.backend != "pallas":
        branches = [_engine_fwd_branch(p, H=H, n_out=rows_pad, slope=slope)
                    for p in g._fwd.pcsrs]

        def body(q2, k2, vf2, colidx, lrow, trow, init, fini, vals,
                 sidx, hsrc):
            kx, vfx = exchange(k2, vf2, sidx[0], hsrc[0])
            i = jax.lax.axis_index(AXIS)
            return jax.lax.switch(i, branches, colidx[0], lrow[0], trow[0],
                                  init[0], fini[0], vals[0], q2, kx, vfx)

        sm = shard_map_2d(body, mesh, 11)

        def f(Q, K, Vf):
            out = sm(g.pad_heads(Q), g.pad_heads(K), g.pad_heads(Vf),
                     *g._fwd.arrays, g._send_idx, g._halo_src)
            return g.unpad_heads(out, H)

        return jax.jit(f)

    # ------------------------- pallas: custom_vjp over the SPMD programs
    pack = g.gat_pack(H)
    fwd_branches = [
        _pallas_fwd_branch(p, H=H, n_out=rows_pad, slope=slope,
                           interpret=g.interpret,
                           logits_pad=pack.logits_pad,
                           stats_pad=pack.stats_pad)
        for p in pack.fwd.pcsrs]

    def fwd_body(q2, k2, vf2, colidx, lrow, trow, init, fini, vals,
                 sidx, hsrc):
        kx, vfx = exchange(k2, vf2, sidx[0], hsrc[0])
        i = jax.lax.axis_index(AXIS)
        return jax.lax.switch(i, fwd_branches, colidx[0], lrow[0],
                              trow[0], init[0], fini[0], vals[0],
                              q2, kx, vfx)

    fwd_sm = shard_map_2d(fwd_body, mesh, 11, n_out=4)

    @jax.jit
    def run_fwd(Q, K, Vf):
        out2, lg, rm, rs = fwd_sm(g.pad_heads(Q), g.pad_heads(K),
                                  g.pad_heads(Vf), *pack.fwd.arrays,
                                  g._send_idx, g._halo_src)
        return g.unpad_heads(out2, H), lg, rm, rs

    state = {}                 # the backward program, built on first use

    def get_bwd():
        if "fn" in state:
            return state["fn"]
        ensure_gat_bwd_pack(pack)
        branches = [
            _pallas_bwd_branch(p, pt, H=H, n_out=rows_pad, slope=slope,
                               interpret=g.interpret)
            for p, pt in zip(pack.fwd.pcsrs, pack.bwd.pcsrs)]
        n_parts, max_send = g.halo.n_parts, g.halo.max_send

        def bwd_body(do2, q2, k2, vf2, fc, fl, ft, fi_, ff, fv,
                     tc, tl, tt, ti, tf_, tv, fidx, tidx, lg, rm, rs,
                     sidx, hsrc):
            # flash-style recompute: re-exchange the K/Vf halo instead of
            # holding the extended operands as residuals
            kx, vfx = exchange(k2, vf2, sidx[0], hsrc[0])
            i = jax.lax.axis_index(AXIS)
            dq2, dkx2, dvfx2 = jax.lax.switch(
                i, branches, fc[0], fl[0], ft[0], fi_[0], ff[0], fv[0],
                tc[0], tl[0], tt[0], ti[0], tf_[0], tv[0],
                fidx[0], tidx[0], do2, q2, kx, vfx, lg[0], rm[0], rs[0])
            # joint halo gradient scatter-back (dK and dVf in one
            # collective), the exact transpose of the forward exchange
            dhalo = jnp.concatenate([dkx2[rows_pad:], dvfx2[rows_pad:]],
                                    axis=1)
            back = halo_scatter_back(dhalo, sidx[0], hsrc[0],
                                     n_parts=n_parts, max_send=max_send,
                                     rows_pad=rows_pad, axis_name=AXIS)
            wk = dkx2.shape[1]
            return (dq2, dkx2[:rows_pad] + back[:, :wk],
                    dvfx2[:rows_pad] + back[:, wk:])

        sm = shard_map_2d(bwd_body, mesh, 23, n_out=3)

        @jax.jit
        def run_bwd(Q, K, Vf, lg, rm, rs, dOut):
            dq2, dk2, dvf2 = sm(g.pad_heads(dOut), g.pad_heads(Q),
                                g.pad_heads(K), g.pad_heads(Vf),
                                *pack.fwd.arrays, *pack.bwd.arrays,
                                pack.f_idx, pack.t_idx, lg, rm, rs,
                                g._send_idx, g._halo_src)
            return tuple(g.unpad_heads(x, H) for x in (dq2, dk2, dvf2))

        state["fn"] = run_bwd
        return run_bwd

    @jax.custom_vjp
    def f(Q, K, Vf):
        return run_fwd(Q, K, Vf)[0]

    def f_fwd(Q, K, Vf):
        out, lg, rm, rs = run_fwd(Q, K, Vf)
        return out, (Q, K, Vf, lg, rm, rs)

    def f_bwd(res, dOut):
        Q, K, Vf, lg, rm, rs = res
        return get_bwd()(Q, K, Vf, lg, rm, rs, dOut)

    f.defvjp(f_fwd, f_bwd)
    return f
