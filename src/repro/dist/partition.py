"""1D row partitioning of a CSR adjacency for multi-device execution.

The graph's rows (= destination nodes) are split into ``n_parts``
contiguous ranges; shard ``p`` owns rows ``[starts[p], starts[p+1])`` and
the matching slice of every node-aligned array (features, labels,
gradients).  Because the split is by *row*, every nonzero of A lands in
exactly one shard — the shard owning its destination row — so SpMM's
scatter side is purely local and only the gather side (columns = source
nodes) crosses shards.

Each shard's columns split into

* **local** columns (sources the shard owns): renumbered ``j - start_p``;
* **halo** columns (sources owned by other shards): the sorted unique
  remote ids become a compact *halo index map* ``halo_global``; halo
  column ``g`` is renumbered ``rows_pad + rank(g)``.

All shards are padded to a uniform ``rows_pad`` row count and
``halo_pad`` halo width so the per-shard arrays stack into one
mesh-sharded tensor (`jax.shard_map` requires uniform block shapes); the
padding never aliases real data — padded rows have no nonzeros and
padded halo columns are referenced by no edge.

Two strategies:

* ``"contiguous"`` — equal row counts (the trivial split);
* ``"balanced"``   — boundaries chosen on the cumulative-nnz curve so
  shards carry ~equal nonzeros (the 1D analogue of the paper's workload
  balancing argument: on power-law graphs equal-row shards differ by
  orders of magnitude in work).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import CSRMatrix

STRATEGIES = ("contiguous", "balanced")


@dataclass
class Shard:
    """One row-range of the global graph, in local (extended-column)
    coordinates."""

    part: int
    start: int               # global row range [start, stop)
    stop: int
    csr: CSRMatrix           # (rows_pad, rows_pad + halo_pad) local CSR
    halo_global: np.ndarray  # (n_halo,) sorted global ids of halo columns
    n_halo: int

    @property
    def n_local_rows(self) -> int:
        return self.stop - self.start


@dataclass
class RowPartition:
    """The full partition plan: boundaries + per-shard local CSRs."""

    n_parts: int
    n_global: int
    strategy: str
    starts: np.ndarray       # (n_parts+1,) global row boundaries
    rows_pad: int            # uniform padded local row count
    halo_pad: int            # uniform padded halo width (≥ 1)
    shards: list

    @property
    def ext_cols(self) -> int:
        """Width of the per-shard extended column space (local + halo)."""
        return self.rows_pad + self.halo_pad

    def owner(self, g):
        """Shard owning global row(s) ``g``."""
        return np.searchsorted(self.starts[1:-1], np.asarray(g), side="right")

    def pad_position(self, g):
        """Position of global row(s) ``g`` in the (P·rows_pad) padded
        layout the mesh shards along its leading axis."""
        own = self.owner(g)
        return own * self.rows_pad + (np.asarray(g) - self.starts[own])


def partition_bounds(csr: CSRMatrix, n_parts: int,
                     strategy: str = "balanced") -> np.ndarray:
    """Row boundaries (n_parts+1,) for the chosen strategy."""
    n = csr.n_rows
    if n_parts < 1 or n_parts > max(1, n):
        raise ValueError(f"n_parts={n_parts} invalid for {n} rows")
    if strategy == "contiguous":
        per = -(-n // n_parts)
        starts = np.minimum(np.arange(n_parts + 1, dtype=np.int64) * per, n)
    elif strategy == "balanced":
        targets = np.linspace(0, csr.nnz, n_parts + 1)[1:-1]
        inner = np.searchsorted(csr.indptr, targets, side="left")
        starts = np.concatenate([[0], inner, [n]]).astype(np.int64)
        starts = np.maximum.accumulate(starts)
    else:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    return starts


def partition_csr(csr: CSRMatrix, n_parts: int,
                  strategy: str = "balanced", *, starts=None,
                  halo_pad_min: int = 1) -> RowPartition:
    """Split ``csr`` into per-shard local CSRs with halo column maps.

    ``starts`` pins explicit row boundaries instead of recomputing them —
    the dynamic per-shard re-pack path re-slices a *mutated* graph under
    the partition the SPMD program was compiled for, so unchanged shards
    come out bit-identical and reusable.  ``halo_pad_min`` floors the
    padded halo width for the same reason: as long as the mutated halos
    still fit the old pad, every per-shard array keeps its shape and the
    compiled programs stay valid.
    """
    if csr.n_rows != csr.n_cols:
        raise ValueError("row partitioning expects a square adjacency")
    if starts is None:
        starts = partition_bounds(csr, n_parts, strategy)
    else:
        starts = np.asarray(starts, np.int64)
        if starts.shape != (n_parts + 1,) or starts[0] != 0 \
                or starts[-1] != csr.n_rows:
            raise ValueError(f"starts must be (n_parts+1,) boundaries "
                             f"over [0, {csr.n_rows}]")
    rows_pad = int(np.max(np.diff(starts))) if n_parts else 0
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), csr.degrees)

    # first pass: per-shard edge slices (CSR rows are sorted ⇒ contiguous)
    slices, halos = [], []
    for p in range(n_parts):
        lo, hi = int(starts[p]), int(starts[p + 1])
        sel = slice(int(csr.indptr[lo]), int(csr.indptr[hi]))
        cols = csr.indices[sel]
        remote = cols[(cols < lo) | (cols >= hi)]
        halos.append(np.unique(remote))
        slices.append((lo, hi, sel))
    halo_pad = max(1, int(halo_pad_min),
                   max((h.shape[0] for h in halos), default=1))

    shards = []
    for p, (lo, hi, sel) in enumerate(slices):
        halo = halos[p]
        r = rows[sel] - lo
        c = csr.indices[sel]
        d = csr.data[sel]
        local = (c >= lo) & (c < hi)
        lc = np.where(local, c - lo,
                      rows_pad + np.searchsorted(halo, c))
        shard_csr = CSRMatrix.from_coo(r, lc, d, rows_pad,
                                       rows_pad + halo_pad,
                                       sum_duplicates=False)
        shards.append(Shard(p, lo, hi, shard_csr, halo,
                            int(halo.shape[0])))
    return RowPartition(n_parts, csr.n_rows, strategy, starts,
                        rows_pad, halo_pad, shards)


def unpartition_rows(part: RowPartition, stacked: np.ndarray) -> np.ndarray:
    """Inverse of the padded layout: (P·rows_pad, ...) → (n_global, ...)."""
    idx = part.pad_position(np.arange(part.n_global, dtype=np.int64))
    return np.asarray(stacked)[idx]


def split_local_halo(shard: Shard, part: RowPartition):
    """Split a shard's local CSR into its **local** and **halo** edge sets
    — the decomposition the halo/compute-overlap path executes.

    The shard CSR spans the extended column space ``[0, rows_pad +
    halo_pad)``.  Edges whose source column is *owned* (``col <
    rows_pad``) need no communication; edges whose source is a halo
    column can only run after the ``all_gather`` lands.  Splitting them
    into two matrices

    * ``local`` — ``(rows_pad, rows_pad)``, owned columns only;
    * ``halo``  — ``(rows_pad, halo_pad)``, halo columns renumbered to
      ``[0, halo_pad)`` so the gathered ``(max_halo, d)`` buffer is its
      operand directly;

    lets ``A_p·B_ext = local·B_loc + halo·B_halo`` — the local SpMM has
    no data dependency on the collective, so the XLA scheduler hides the
    gather latency behind it (see docs/DISTRIBUTED.md §Overlap).  Each
    sub-matrix gets its own cost-model-selected ⟨W,F,V,S⟩: the halo part
    of a power-law shard is typically far sparser and more scattered
    than the local part, so the configs genuinely differ.
    """
    csr = shard.csr
    rows_pad, halo_pad = part.rows_pad, part.halo_pad
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), csr.degrees)
    local = csr.indices < rows_pad
    loc = CSRMatrix.from_coo(rows[local], csr.indices[local],
                             csr.data[local], rows_pad, rows_pad,
                             sum_duplicates=False)
    halo = CSRMatrix.from_coo(rows[~local], csr.indices[~local] - rows_pad,
                              csr.data[~local], rows_pad, halo_pad,
                              sum_duplicates=False)
    return loc, halo
