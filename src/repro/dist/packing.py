"""Mesh plumbing shared by the distributed operators.

Two pieces every SPMD program in ``repro.dist`` is built from:

* ``shard_map_2d`` — the one ``jax.shard_map`` wrapper: every operand is
  a rank-2 array sharded along the leading ``("parts",)`` axis unless
  listed in ``replicated`` (read whole by every shard, e.g. a
  per-feature bias row) and every output is sharded the same way unless
  an explicit ``out_specs`` says otherwise (the fused backward returns a
  *replicated* ``dbias`` produced by an in-program ``psum``).
* ``pack_shards`` — per-shard *covered* PCSR steering arrays
  (``PCSR.steering(H, covered=True)``) padded to uniform shapes and
  stacked along a leading partition axis, so one mesh-sharded tensor
  carries every shard's (different-config!) steering data.  ``H > 1``
  packs the head-tiled arrays: per head the real chunks come first and
  the coverage chunks last, so a branch can recover the *uncovered*
  arrays by reshaping ``(H, C_cov·m)`` and slicing ``[:, :C·m]`` — no
  gather, no second pack (the prefix property the GAT branches rely on).

Config heterogeneity is per *shard*: each partition's cost model may
pick a different ⟨W, F, V, S⟩ — including the balanced ``B`` chunk
schedule for degree-skewed partitions — and the pack only ever sees the
resulting steering arrays (padded to the max C·K across shards), so
balanced and uniform shards coexist in one mesh tensor with no special
casing here.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec

try:                                       # jax ≥ 0.6 top-level export
    from jax import shard_map as _shard_map_raw
except ImportError:                        # 0.4.x experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_raw

AXIS = "parts"


def shard_map_2d(f, mesh, n_in: int, replicated: tuple = (),
                 n_out: int = 1, out_specs=None):
    """Wrap ``f`` in a ``shard_map`` over the partition mesh.

    Every argument is sharded ``PartitionSpec("parts", None)`` except the
    ``replicated`` indices (read whole by every shard).  ``n_out > 1``
    shards every output the same way; pass ``out_specs`` explicitly when
    an output is replicated (e.g. a ``psum``-reduced bias gradient).
    """
    spec = PartitionSpec(AXIS, None)
    rspec = PartitionSpec(None, None)
    in_specs = tuple(rspec if i in replicated else spec
                     for i in range(n_in))
    if out_specs is None:
        out_specs = spec if n_out == 1 else (spec,) * n_out
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return _shard_map_raw(f, check_rep=False, **kwargs)
    except TypeError:                      # newer jax dropped check_rep
        return _shard_map_raw(f, **kwargs)


@dataclass
class PackedShards:
    """Per-shard *covered* PCSR steering arrays (every block visited —
    ``PCSR.steering(covered=True)``) padded to uniform shapes and stacked
    along a leading partition axis (device arrays).  Coverage chunks come
    after the real ones *within each head's segment*, so an engine branch
    slicing the uncovered prefix and a Pallas branch slicing the covered
    length read the same pack."""

    pcsrs: list                  # per-shard PCSR (host; static shapes)
    colidx: jnp.ndarray          # (P, S_max) int32
    lrow: jnp.ndarray            # (P, S_max) int32
    trow: jnp.ndarray            # (P, C_max) int32
    init: jnp.ndarray            # (P, C_max) int32
    fini: jnp.ndarray            # (P, C_max) int32 — last chunk of block
    vals: jnp.ndarray            # (P, VS_max) float32, flattened (C,V,K)

    @property
    def arrays(self) -> tuple:
        """The six mesh-sharded steering operands, in the branch-argument
        order every SPMD body uses."""
        return (self.colidx, self.lrow, self.trow, self.init, self.fini,
                self.vals)


def pack_shards(pcsrs, H: int = 1) -> PackedShards:
    """Stack the shards' covered (optionally ``H``-head-tiled) steering
    arrays into mesh-shardable tensors, zero-padded to the maxima."""
    P = len(pcsrs)
    sts = [p.steering(H, covered=True) for p in pcsrs]
    S = max(s["colidx"].shape[0] for s in sts)
    C = max(s["trow"].shape[0] for s in sts)
    VS = max(s["vals"].size for s in sts)
    colidx = np.zeros((P, S), np.int32)
    lrow = np.zeros((P, S), np.int32)
    trow = np.zeros((P, C), np.int32)
    init = np.zeros((P, C), np.int32)
    fini = np.zeros((P, C), np.int32)
    vals = np.zeros((P, VS), np.float32)
    for i, s in enumerate(sts):
        colidx[i, :s["colidx"].shape[0]] = s["colidx"]
        lrow[i, :s["lrow"].shape[0]] = s["lrow"]
        trow[i, :s["trow"].shape[0]] = s["trow"]
        init[i, :s["init"].shape[0]] = s["init"]
        fini[i, :s["fini"].shape[0]] = s["fini"]
        vals[i, :s["vals"].size] = s["vals"].reshape(-1)
    # packs are built lazily — sometimes inside a backward trace — and
    # cached on the DistGraph; force concrete (non-tracer) device arrays
    # so the cache is safe to reuse across traces
    with jax.ensure_compile_time_eval():
        return PackedShards(list(pcsrs), *map(jnp.asarray,
                                              (colidx, lrow, trow, init,
                                               fini, vals)))
