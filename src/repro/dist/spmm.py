"""Distributed SpMM / GAT message over a partitioned PCSR.

``DistGraph`` turns one global adjacency into a mesh of per-shard PCSR
operators: the rows are 1D-partitioned (``partition.py``), each shard's
local CSR gets its *own* ⟨W,F,V,S⟩ configuration — chosen by
``CostModel.best`` (or a trained decider) on that shard's features — and
the per-shard packed arrays are padded to uniform shapes and sharded
over a ``("parts",)`` device mesh.

Execution is one SPMD ``shard_map`` program:

1. **halo exchange** (``halo.py``) — one compacted ``all_gather`` brings
   the remote source rows each shard needs; they concatenate after the
   local feature block to form the extended column space the local PCSR
   indexes.  SpMM and SDDMM on the shard reuse the same exchange.
2. **per-shard compute** — ``lax.switch`` on ``axis_index("parts")``
   dispatches to a per-partition branch closed over that shard's
   *static* PCSR shapes (C, K, V, R, n_blocks), so partitions genuinely
   run different configurations inside a single SPMD program.  Branches
   call the existing engine traversal (pure JAX) or the Pallas kernel
   (``backend="pallas"``).
3. **``dist_spmm`` backward** — a ``custom_vjp`` whose backward runs the
   per-shard *transpose* PCSR (``dB_ext = A_pᵀ·dC_p``) and scatters the
   halo block of the gradient back to its owner shards through
   ``halo_scatter_back`` (scatter → ``psum_scatter`` → local add), the
   exact transpose of the forward exchange.

``DistGraph.fused`` is the epilogue-fused distributed aggregation:
scale/bias/activation applied per shard inside the SPMD program
(in-kernel on Pallas branches via the covered steering pack's ``fini``
arrays, XLA-fused into the engine branches) — no global elementwise pass
follows the halo'd SpMM.

``dist_gat_message`` runs SDDMM → LeakyReLU → edge softmax → SpMM per
shard.  Row partitioning keeps every destination row's full edge set on
one shard, so edge softmax needs no communication — only the K/Vf halo
exchange (done once, jointly) crosses the mesh.  The engine path is
natively differentiable; halo gradients flow back through the autodiff
transpose of ``all_gather`` (a ``psum_scatter``), i.e. the same reverse
path the explicit SpMM backward takes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CostModel, CSRMatrix, SpMMConfig, build_pcsr,
                        config_space, extract_features)
from repro.core.engine import (_engine, _engine_sddmm, _slot_rows,
                               apply_epilogue, attend_scores,
                               epilogue_grad)

from .halo import HaloSpec, build_halo, halo_exchange, halo_scatter_back
from .partition import RowPartition, partition_csr

try:                                       # jax ≥ 0.6 top-level export
    from jax import shard_map as _shard_map_raw
except ImportError:                        # 0.4.x experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_raw

from jax.sharding import PartitionSpec

AXIS = "parts"


def _shard_map(f, mesh, n_in: int, replicated: tuple = ()):
    """Shard every arg along the mesh axis except the ``replicated``
    argument indices (e.g. a per-feature bias every shard reads whole)."""
    spec = PartitionSpec(AXIS, None)
    rspec = PartitionSpec(None, None)
    in_specs = tuple(rspec if i in replicated else spec
                     for i in range(n_in))
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=spec)
    try:
        return _shard_map_raw(f, check_rep=False, **kwargs)
    except TypeError:                      # newer jax dropped check_rep
        return _shard_map_raw(f, **kwargs)


# ------------------------------------------------------------- packing
@dataclass
class PackedShards:
    """Per-shard *covered* PCSR steering arrays (every block visited —
    ``PCSR.steering(covered=True)``) padded to uniform shapes and stacked
    along a leading partition axis (device arrays).  Coverage chunks come
    after the real ones, so an engine branch slicing the uncovered prefix
    and a Pallas branch slicing the covered length read the same pack."""

    pcsrs: list                  # per-shard PCSR (host; static shapes)
    colidx: jnp.ndarray          # (P, S_max) int32
    lrow: jnp.ndarray            # (P, S_max) int32
    trow: jnp.ndarray            # (P, C_max) int32
    init: jnp.ndarray            # (P, C_max) int32
    fini: jnp.ndarray            # (P, C_max) int32 — last chunk of block
    vals: jnp.ndarray            # (P, VS_max) float32, flattened (C,V,K)


def pack_shards(pcsrs) -> PackedShards:
    P = len(pcsrs)
    sts = [p.steering(covered=True) for p in pcsrs]
    S = max(s["colidx"].shape[0] for s in sts)
    C = max(s["trow"].shape[0] for s in sts)
    VS = max(s["vals"].size for s in sts)
    colidx = np.zeros((P, S), np.int32)
    lrow = np.zeros((P, S), np.int32)
    trow = np.zeros((P, C), np.int32)
    init = np.zeros((P, C), np.int32)
    fini = np.zeros((P, C), np.int32)
    vals = np.zeros((P, VS), np.float32)
    for i, s in enumerate(sts):
        colidx[i, :s["colidx"].shape[0]] = s["colidx"]
        lrow[i, :s["lrow"].shape[0]] = s["lrow"]
        trow[i, :s["trow"].shape[0]] = s["trow"]
        init[i, :s["init"].shape[0]] = s["init"]
        fini[i, :s["fini"].shape[0]] = s["fini"]
        vals[i, :s["vals"].size] = s["vals"].reshape(-1)
    return PackedShards(list(pcsrs), *map(jnp.asarray,
                                          (colidx, lrow, trow, init, fini,
                                           vals)))


def _spmm_branch(pcsr, *, n_out: int, backend: str, interpret: bool,
                 epilogue: bool = False, activation: str = "none"):
    """Branch computing ``A_p · B_ext`` with shard-``p``-static shapes.

    With ``epilogue=True`` the branch takes two extra operands — the
    shard's per-row scale column and the replicated per-feature bias row —
    and applies scale/bias/activation per shard: in-kernel on the Pallas
    backend (the fused epilogue), XLA-fused into the SPMD program on the
    engine backend."""
    cfg = pcsr.config
    C, K, V, R, nb = pcsr.num_chunks, pcsr.K, cfg.V, cfg.R, pcsr.n_blocks
    S, VS = C * K, C * V * K

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import _call as _pallas_call
        Cc = pcsr.steering(covered=True)["trow"].shape[0]
        Sc, VSc = Cc * K, Cc * V * K

        def branch(colidx, lrow, trow, init, fini, vals, b_ext, *ep):
            kw = {}
            if epilogue:
                kw = dict(scale=ep[0][:, 0], bias=ep[1][0],
                          activation=activation)
            return _pallas_call(
                colidx[:Sc], lrow[:Sc], trow[:Cc], init[:Cc], fini[:Cc],
                vals[:VSc].reshape(Cc, V, K), b_ext,
                n_blocks=nb, R=R, V=V, K=K, dblk=cfg.dblk,
                n_rows=n_out, dim=b_ext.shape[1], interpret=interpret, **kw)
        return branch

    def branch(colidx, lrow, trow, init, fini, vals, b_ext, *ep):
        out = _engine(colidx[:S], lrow[:S], trow[:C],
                      vals[:VS].reshape(C, V, K), b_ext,
                      V=V, R=R, K=K, n_blocks=nb, n_rows=n_out)
        if epilogue:
            out = apply_epilogue(out, ep[0][:, 0], ep[1][0], activation)
        return out
    return branch


def _gat_branch(pcsr, *, n_out: int, slope: float):
    """Branch computing the full per-shard attention message (engine)."""
    cfg = pcsr.config
    C, K, V, R, nb = pcsr.num_chunks, pcsr.K, cfg.V, cfg.R, pcsr.n_blocks
    S, VS = C * K, C * V * K

    def branch(colidx, lrow, trow, init, fini, vals, q, k_ext, vf_ext):
        ci, lr, tr = colidx[:S], lrow[:S], trow[:C]
        vv = vals[:VS].reshape(C, V, K)
        scores = _engine_sddmm(ci, lr, tr, vv, q, k_ext, V=V, R=R, K=K)
        rows = _slot_rows(lr, tr, V=V, R=R, K=K)
        alpha = attend_scores(scores, vv != 0, rows, nb * R,
                              dim_k=q.shape[1], slope=slope)
        return _engine(ci, lr, tr, alpha, vf_ext,
                       V=V, R=R, K=K, n_blocks=nb, n_rows=n_out)
    return branch


# ----------------------------------------------------------- DistGraph
class DistGraph:
    """Partitioned graph operator: per-shard adaptive PCSR on a mesh.

    Configuration resolution per shard: explicit ``configs`` (one or a
    per-shard list) > ``decider`` prediction on the shard's features >
    ``CostModel.best`` on the shard's local CSR with ``op`` pricing —
    so a power-law graph's hub shard and tail shards pick *different*
    ⟨W,F,V,S⟩, the cross-shard form of the paper's adaptivity claim.
    """

    def __init__(self, csr: CSRMatrix, dim: int, n_parts: int, *,
                 strategy: str = "balanced",
                 configs=None,
                 decider=None,
                 mesh=None,
                 backend: str = "engine",
                 interpret: bool = True,
                 op: str = "spmm",
                 max_f: int = 4):
        self.csr = csr
        self.dim = dim
        self.backend = backend
        self.interpret = interpret
        self.part: RowPartition = partition_csr(csr, n_parts, strategy)
        self.halo: HaloSpec = build_halo(self.part)
        self._mesh = mesh                  # resolved lazily: the host-side
        # plan (partition, configs, packing) needs no devices at all

        space = config_space(dim, max_f)
        self.predicted_times: list = []
        if configs is None:
            if decider is not None:
                self.configs = [decider.predict(extract_features(s.csr), dim)
                                for s in self.part.shards]
            else:
                self.configs = []
                for s in self.part.shards:
                    cfg, t = CostModel(s.csr).best(dim, space, op=op)
                    self.configs.append(cfg)
                    self.predicted_times.append(t)
        elif isinstance(configs, SpMMConfig):
            self.configs = [configs] * n_parts
        else:
            self.configs = list(configs)
            if len(self.configs) != n_parts:
                raise ValueError("configs list must have one entry per shard")

        self._fwd = pack_shards(
            [build_pcsr(s.csr.indptr, s.csr.indices, s.csr.data,
                        s.csr.n_rows, s.csr.n_cols, cfg)
             for s, cfg in zip(self.part.shards, self.configs)])
        self._bwd_pack = None              # transpose PCSRs built on first
        # backward only — forward-only / GAT (engine-autodiff) use skips it
        self._send_idx = jnp.asarray(self.halo.send_idx)
        self._halo_src = jnp.asarray(self.halo.halo_src)

        # global ↔ padded-layout row maps
        g = np.arange(self.part.n_global, dtype=np.int64)
        pad_pos = self.part.pad_position(g)
        n_pad = self.part.n_parts * self.part.rows_pad
        pad_src = np.zeros(n_pad, np.int32)
        pad_valid = np.zeros(n_pad, bool)
        pad_src[pad_pos] = g
        pad_valid[pad_pos] = True
        self._pad_pos = jnp.asarray(pad_pos.astype(np.int32))
        self._pad_src = jnp.asarray(pad_src)
        self._pad_valid = jnp.asarray(pad_valid)

        self._spmm_fn = None               # built lazily (first call) so a
        self._gat_fns: dict = {}           # host-side plan needs no devices
        self._fused_fns: dict = {}         # per-activation fused programs
        self._bwd_fn = None                # shared transpose-path shard_map

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_partition_mesh
            self._mesh = make_partition_mesh(self.part.n_parts)
        return self._mesh

    @property
    def _bwd(self) -> PackedShards:
        if self._bwd_pack is None:
            bwd = []
            for s, cfg in zip(self.part.shards, self.configs):
                t = s.csr.transpose()      # (ext_cols, rows_pad)
                bwd.append(build_pcsr(t.indptr, t.indices, t.data,
                                      t.n_rows, t.n_cols, cfg))
            self._bwd_pack = pack_shards(bwd)
        return self._bwd_pack

    # ---------------------------------------------------------- layout
    def pad(self, x):
        """(n_global, d) → (P·rows_pad, d) padded mesh layout."""
        x = jnp.asarray(x)
        return jnp.where(self._pad_valid[:, None],
                         jnp.take(x, self._pad_src, axis=0), 0)

    def unpad(self, x):
        """(P·rows_pad, d) padded mesh layout → (n_global, d)."""
        return jnp.take(x, self._pad_pos, axis=0)

    # ------------------------------------------------------- operators
    def spmm(self, B):
        """C = A·B, distributed; (n_global, d) → (n_global, d)."""
        if self._spmm_fn is None:
            self._spmm_fn = _build_dist_spmm(self)
        return self._spmm_fn(B)

    __call__ = spmm

    def fused(self, B, scale=None, bias=None, activation: str = "none"):
        """Epilogue-fused distributed aggregation
        ``act(scale ⊙ (A·B) + bias)`` — scale/bias/activation are applied
        *per shard inside the SPMD program* (in-kernel on the Pallas
        backend, XLA-fused into the branch on the engine backend), so no
        separate global elementwise pass follows the halo'd SpMM.
        Differentiable in ``B`` and ``bias``; ``scale`` (degree data) is a
        constant."""
        if activation not in self._fused_fns:
            self._fused_fns[activation] = _build_dist_fused_spmm(
                self, activation=activation)
        n, d = self.part.n_global, jnp.shape(B)[-1]
        scale = jnp.ones(n, jnp.float32) if scale is None \
            else jnp.asarray(scale)
        bias_arr = jnp.zeros(d, jnp.float32) if bias is None \
            else jnp.asarray(bias)
        out = self._fused_fns[activation](B, scale, bias_arr)
        return out

    def gat_message(self, Q, K, Vf, *, slope: float = 0.2):
        """Distributed GAT message (single-head, engine backend)."""
        if jnp.ndim(Q) == 3:
            raise NotImplementedError(
                "dist_gat_message is single-head; vmap heads outside or "
                "fold them into the feature dim")
        if slope not in self._gat_fns:
            self._gat_fns[slope] = _build_dist_gat(self, slope=slope)
        return self._gat_fns[slope](Q, K, Vf)


def _dist_bwd_transpose(g: DistGraph):
    """The transpose-path backward ``dB = Aᵀ·dC`` with halo scatter-back,
    built lazily on the first backward trace (forward-only use never
    builds the transpose PCSRs) and shared between the plain and the
    epilogue-fused distributed SpMM."""
    if g._bwd_fn is None:
        rows_pad, ext = g.part.rows_pad, g.part.ext_cols
        n_parts, max_send = g.halo.n_parts, g.halo.max_send
        bwd_branches = [_spmm_branch(p, n_out=ext, backend=g.backend,
                                     interpret=g.interpret)
                        for p in g._bwd.pcsrs]

        def bwd_body(dc, colidx, lrow, trow, init, fini, vals, sidx, hsrc):
            i = jax.lax.axis_index(AXIS)
            d_ext = jax.lax.switch(i, bwd_branches, colidx[0], lrow[0],
                                   trow[0], init[0], fini[0], vals[0], dc)
            back = halo_scatter_back(d_ext[rows_pad:], sidx[0], hsrc[0],
                                     n_parts=n_parts, max_send=max_send,
                                     rows_pad=rows_pad, axis_name=AXIS)
            return d_ext[:rows_pad] + back

        sm = _shard_map(bwd_body, g.mesh, 9)

        def run(dC):
            dB = sm(g.pad(dC), g._bwd.colidx, g._bwd.lrow, g._bwd.trow,
                    g._bwd.init, g._bwd.fini, g._bwd.vals,
                    g._send_idx, g._halo_src)
            return g.unpad(dB)

        g._bwd_fn = jax.jit(run)   # cache the SPMD trace across steps
    return g._bwd_fn


def _build_dist_spmm(g: DistGraph):
    """The ``custom_vjp`` distributed SpMM closed over one DistGraph."""
    fwd_branches = [_spmm_branch(p, n_out=g.part.rows_pad,
                                 backend=g.backend, interpret=g.interpret)
                    for p in g._fwd.pcsrs]

    def fwd_body(b, colidx, lrow, trow, init, fini, vals, sidx, hsrc):
        halo = halo_exchange(b, sidx[0], hsrc[0], axis_name=AXIS)
        b_ext = jnp.concatenate([b, halo], axis=0)
        i = jax.lax.axis_index(AXIS)
        return jax.lax.switch(i, fwd_branches, colidx[0], lrow[0],
                              trow[0], init[0], fini[0], vals[0], b_ext)

    fwd_sm = _shard_map(fwd_body, g.mesh, 9)

    def run_fwd(B):
        out = fwd_sm(g.pad(B), g._fwd.colidx, g._fwd.lrow, g._fwd.trow,
                     g._fwd.init, g._fwd.fini, g._fwd.vals,
                     g._send_idx, g._halo_src)
        return g.unpad(out)

    @jax.custom_vjp
    def f(B):
        return run_fwd(B)

    def f_fwd(B):
        return run_fwd(B), None

    def f_bwd(_, dC):
        return (_dist_bwd_transpose(g)(dC),)

    f.defvjp(f_fwd, f_bwd)
    return jax.jit(f)          # cache the SPMD trace across training steps


def _build_dist_fused_spmm(g: DistGraph, *, activation: str):
    """Epilogue-fused distributed SpMM: one SPMD program whose per-shard
    branches apply scale/bias/activation where the output is produced —
    in-kernel (Pallas) or XLA-fused into the branch (engine) — so the
    fused distributed GCN layer runs no global elementwise pass after the
    halo'd SpMM.  A ``custom_vjp`` over (B, bias): the backward reuses the
    shared transpose path on ``scale ⊙ (dOut ⊙ act'(out))`` and reduces
    ``dbias`` over rows, mirroring the single-device fused closure."""
    rows_pad = g.part.rows_pad
    branches = [_spmm_branch(p, n_out=rows_pad, backend=g.backend,
                             interpret=g.interpret, epilogue=True,
                             activation=activation)
                for p in g._fwd.pcsrs]

    def body(b, colidx, lrow, trow, init, fini, vals, sidx, hsrc, sc, bi):
        halo = halo_exchange(b, sidx[0], hsrc[0], axis_name=AXIS)
        b_ext = jnp.concatenate([b, halo], axis=0)
        i = jax.lax.axis_index(AXIS)
        return jax.lax.switch(i, branches, colidx[0], lrow[0], trow[0],
                              init[0], fini[0], vals[0], b_ext, sc, bi)

    sm = _shard_map(body, g.mesh, 11, replicated=(10,))

    @jax.jit                       # cache the SPMD trace across steps;
    def run_fwd(B, scale, bias):   # the custom_vjp wrapper stays unjitted
        out = sm(g.pad(B), g._fwd.colidx, g._fwd.lrow, g._fwd.trow,
                 g._fwd.init, g._fwd.fini, g._fwd.vals,
                 g._send_idx, g._halo_src,
                 g.pad(scale[:, None]), bias[None, :])
        return g.unpad(out)

    @jax.custom_vjp
    def f(B, scale, bias):
        return run_fwd(B, scale, bias)

    def f_fwd(B, scale, bias):
        out = run_fwd(B, scale, bias)
        return out, (out, scale)

    def f_bwd(res, dOut):
        out, scale = res
        dpre = epilogue_grad(out, dOut, activation)
        dbias = dpre.sum(axis=0)
        dB = _dist_bwd_transpose(g)(dpre * scale[:, None])
        # scale is graph data (degree norms), not a trained parameter
        return dB, jnp.zeros_like(scale), dbias

    f.defvjp(f_fwd, f_bwd)
    return f


def _build_dist_gat(g: DistGraph, *, slope: float):
    """Distributed attention message; K/Vf halo-exchanged jointly."""
    rows_pad = g.part.rows_pad
    branches = [_gat_branch(p, n_out=rows_pad, slope=slope)
                for p in g._fwd.pcsrs]

    def body(q, k, vf, colidx, lrow, trow, init, fini, vals, sidx, hsrc):
        dk = k.shape[1]
        # one exchange serves both operands of the shard's SDDMM + SpMM
        halo = halo_exchange(jnp.concatenate([k, vf], axis=1),
                             sidx[0], hsrc[0], axis_name=AXIS)
        k_ext = jnp.concatenate([k, halo[:, :dk]], axis=0)
        vf_ext = jnp.concatenate([vf, halo[:, dk:]], axis=0)
        i = jax.lax.axis_index(AXIS)
        return jax.lax.switch(i, branches, colidx[0], lrow[0], trow[0],
                              init[0], fini[0], vals[0], q, k_ext, vf_ext)

    sm = _shard_map(body, g.mesh, 11)

    def f(Q, K, Vf):
        out = sm(g.pad(Q), g.pad(K), g.pad(Vf),
                 g._fwd.colidx, g._fwd.lrow, g._fwd.trow, g._fwd.init,
                 g._fwd.fini, g._fwd.vals, g._send_idx, g._halo_src)
        return g.unpad(out)

    return jax.jit(f)          # cache the SPMD trace across training steps


# ------------------------------------------------------ functional API
def dist_spmm(graph: DistGraph, B):
    """C = A·B over a partitioned graph; (n, d) global in and out."""
    return graph.spmm(B)


def dist_gat_message(graph: DistGraph, Q, K, Vf, *, slope: float = 0.2):
    """Distributed SDDMM → LeakyReLU → edge softmax → SpMM message."""
    return graph.gat_message(Q, K, Vf, slope=slope)
