"""Distributed SpMM / GAT message over a partitioned PCSR.

``DistGraph`` turns one global adjacency into a mesh of per-shard PCSR
operators: the rows are 1D-partitioned (``partition.py``), each shard's
local CSR gets its *own* ⟨W,F,V,S⟩ configuration — chosen by
``CostModel.best`` (or a trained decider) on that shard's features, and
priced per head count when the graph aggregates a multi-head GAT — and
the per-shard packed arrays are padded to uniform shapes and sharded
over a ``("parts",)`` device mesh (``packing.py``).

Execution is one SPMD ``shard_map`` program:

1. **halo exchange** (``halo.py``) — one compacted ``all_gather`` brings
   the remote source rows each shard needs; they concatenate after the
   local feature block to form the extended column space the local PCSR
   indexes.  SpMM and SDDMM on the shard reuse the same exchange.
2. **per-shard compute** — ``lax.switch`` on ``axis_index("parts")``
   dispatches to a per-partition branch closed over that shard's
   *static* PCSR shapes (C, K, V, R, n_blocks), so partitions genuinely
   run different configurations inside a single SPMD program.  Branches
   call the existing engine traversal (pure JAX) or the Pallas kernel
   (``backend="pallas"``).
3. **``dist_spmm`` backward** — a ``custom_vjp`` whose backward runs the
   per-shard *transpose* PCSR (``dB_ext = A_pᵀ·dC_p``) and scatters the
   halo block of the gradient back to its owner shards through
   ``halo_scatter_back`` (scatter → ``psum_scatter`` → local add), the
   exact transpose of the forward exchange.

``DistGraph(overlap=True)`` switches the SpMM paths to the **halo/compute
overlap** decomposition: each shard's matrix splits into a *local* part
(owned source columns) and a *halo* part (remote columns, operating
directly on the gathered buffer) — ``partition.split_local_halo`` — each
under its own cost-model-selected config.  The local SpMM has no data
dependency on the ``all_gather``, so the XLA scheduler hides the gather
latency behind it; the backward mirrors this by issuing the halo
gradients' ``psum_scatter`` before the local transpose SpMM runs.  See
docs/DISTRIBUTED.md §Overlap for the timeline.

``DistGraph.fused`` is the epilogue-fused distributed aggregation:
scale/bias/activation applied per shard inside the SPMD program
(in-kernel on Pallas branches via the covered steering pack's ``fini``
arrays, XLA-fused into the engine branches) — no global elementwise pass
follows the halo'd SpMM.  Its backward runs ONE shard_map program that
folds the ``dbias`` reduction in as a ``psum`` (a replicated output of
the same SPMD program that computes ``dB``), so nothing about the fused
backward happens outside the mesh.

``dist_gat_message`` (``gat.py``) runs the attention message per shard —
multi-head, two Pallas kernels per shard forward and an all-Pallas
flash-recompute backward on the Pallas backend.  Row partitioning keeps
every destination row's full edge set on one shard, so edge softmax
needs no communication — only the joint K/Vf halo exchange (and, in the
backward, the dK/dVf halo gradient scatter) crosses the mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec

from repro.core import (CostModel, CSRMatrix, SpMMConfig, build_pcsr,
                        config_space, extract_features)
from repro.core.cost_model import halo_exchange_cost, overlap_exposed_cost
from repro.core.engine import _engine, apply_epilogue, epilogue_grad
from repro.obs import metrics as _obs_metrics, trace as _obs_trace

from .gat import build_dist_gat, build_gat_pack
from .halo import HaloSpec, build_halo, halo_exchange, halo_scatter_back
from .packing import AXIS, PackedShards, pack_shards, shard_map_2d
from .partition import RowPartition, partition_csr, split_local_halo


def _spmm_branch(pcsr, *, n_out: int, backend: str, interpret: bool,
                 epilogue: bool = False, activation: str = "none"):
    """Branch computing ``A_p · B_ext`` with shard-``p``-static shapes.

    With ``epilogue=True`` the branch takes two extra operands — the
    shard's per-row scale column and the replicated per-feature bias row —
    and applies scale/bias/activation per shard: in-kernel on the Pallas
    backend (the fused epilogue), XLA-fused into the SPMD program on the
    engine backend."""
    cfg = pcsr.config
    C, K, V, R, nb = pcsr.num_chunks, pcsr.K, cfg.V, cfg.R, pcsr.n_blocks
    S, VS = C * K, C * V * K

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import _call as _pallas_call
        Cc = pcsr.covered_num_chunks
        Sc, VSc = Cc * K, Cc * V * K

        def branch(colidx, lrow, trow, init, fini, vals, b_ext, *ep):
            kw = {}
            if epilogue:
                kw = dict(scale=ep[0][:, 0], bias=ep[1][0],
                          activation=activation)
            return _pallas_call(
                colidx[:Sc], lrow[:Sc], trow[:Cc], init[:Cc], fini[:Cc],
                vals[:VSc].reshape(Cc, V, K), b_ext,
                n_blocks=nb, R=R, V=V, K=K, dblk=cfg.dblk,
                n_rows=n_out, dim=b_ext.shape[1], interpret=interpret, **kw)
        return branch

    def branch(colidx, lrow, trow, init, fini, vals, b_ext, *ep):
        out = _engine(colidx[:S], lrow[:S], trow[:C],
                      vals[:VS].reshape(C, V, K), b_ext,
                      V=V, R=R, K=K, n_blocks=nb, n_rows=n_out)
        if epilogue:
            out = apply_epilogue(out, ep[0][:, 0], ep[1][0], activation)
        return out
    return branch


# ----------------------------------------------------------- DistGraph
class DistGraph:
    """Partitioned graph operator: per-shard adaptive PCSR on a mesh.

    Configuration resolution per shard: explicit ``configs`` (one or a
    per-shard list) > ``decider`` prediction on the shard's features >
    ``CostModel.best`` on the shard's local CSR with ``op``/``heads``
    pricing — so a power-law graph's hub shard and tail shards pick
    *different* ⟨W,F,V,S⟩, the cross-shard form of the paper's
    adaptivity claim.

    Parameters
    ----------
    csr : CSRMatrix
        The global (square) adjacency; rows are destination nodes.
    dim : int
        Feature width the configs are priced for.
    n_parts : int
        Number of row shards (= mesh devices on first call).
    strategy : ``"balanced"`` (equal-nnz boundaries) or ``"contiguous"``.
    calibration : optional ``repro.core.calibrate.CalibrationResult`` (or
        artifact path) — the per-shard ``CostModel.best`` selection then
        prices through coefficients fitted to measured kernel time on
        this host instead of the hand-set analytic constants.
    heads : int
        Head count the cost model prices the configs for
        (``CostModel.best(..., H=heads)``): head tiling multiplies the
        grid and shrinks the per-head lane width, so the per-shard
        optimum genuinely changes with H.  ``gat_message`` accepts any
        head count at call time regardless.
    overlap : bool
        Run the SpMM paths under the halo/compute-overlap decomposition
        (local + halo sub-matrices per shard, each with its own config;
        the gather hides behind the local SpMM).  GAT's attention chain
        (gather → SDDMM → softmax → SpMM) leaves nothing independent of
        the gather to overlap with, so ``gat_message`` always runs the
        joint-exchange path.
    backend : ``"engine"`` (pure JAX) or ``"pallas"`` (TPU kernels,
        interpret-mode on CPU).
    op : operator the per-shard configs are priced for
        (``"spmm"`` | ``"sddmm"`` | ``"gat"``).

    Construction is a device-free host-side plan (partition, halo maps,
    per-shard config selection, packing); the mesh is resolved on the
    first call.
    """

    def __init__(self, csr: CSRMatrix, dim: int, n_parts: int, *,
                 strategy: str = "balanced",
                 configs=None,
                 decider=None,
                 calibration=None,
                 mesh=None,
                 backend: str = "engine",
                 interpret: bool = True,
                 op: str = "spmm",
                 heads: int = 1,
                 overlap: bool = False,
                 max_f: int = 4):
        self.csr = csr
        self.dim = dim
        self.backend = backend
        self.interpret = interpret
        self.heads = heads
        self.overlap = overlap
        self.part: RowPartition = partition_csr(csr, n_parts, strategy)
        self.halo: HaloSpec = build_halo(self.part)
        self._mesh = mesh                  # resolved lazily: the host-side
        # plan (partition, configs, packing) needs no devices at all

        # per-shard selection prices through a calibration artifact when
        # one is given (path or CalibrationResult) — the per-shard
        # adaptivity claim is only honest under fitted-to-hardware prices
        if calibration is not None and not hasattr(calibration, "price"):
            from repro.core.calibrate import CalibrationResult
            calibration = CalibrationResult.load(calibration)
        self.calibration = calibration

        space = config_space(dim, max_f)
        self.predicted_times: list = []
        if configs is None:
            if decider is not None:
                with _obs_trace.span("dist.select_configs", picker="decider",
                                     n_parts=n_parts):
                    self.configs = [
                        decider.predict(extract_features(s.csr), dim)
                        for s in self.part.shards]
            else:
                self.configs = []
                with _obs_trace.span("dist.select_configs",
                                     picker="cost_model", n_parts=n_parts):
                    for s in self.part.shards:
                        cfg, t = CostModel(s.csr,
                                           calibration=calibration).best(
                            dim, space, op=op, H=heads)
                        self.configs.append(cfg)
                        self.predicted_times.append(t)
        elif isinstance(configs, SpMMConfig):
            self.configs = [configs] * n_parts
        else:
            self.configs = list(configs)
            if len(self.configs) != n_parts:
                raise ValueError("configs list must have one entry per shard")

        with _obs_trace.span("dist.pack", n_parts=n_parts):
            self._fwd = pack_shards(
                [build_pcsr(s.csr.indptr, s.csr.indices, s.csr.data,
                            s.csr.n_rows, s.csr.n_cols, cfg)
                 for s, cfg in zip(self.part.shards, self.configs)])

        # overlap mode: split every shard into local + halo sub-matrices,
        # each under its own cost-model-selected config (the halo part of
        # a power-law shard is typically much sparser than the local one)
        self.overlap_configs: list = []
        self._split_csrs: list = []
        self._loc = self._halo_pack = None
        if overlap:
            loc_pcsrs, halo_pcsrs = [], []
            for i, s in enumerate(self.part.shards):
                loc, hal = split_local_halo(s, self.part)
                self._split_csrs.append((loc, hal))
                if configs is not None:
                    lc = hc = self.configs[i]
                elif decider is not None:
                    lc = decider.predict(extract_features(loc), dim)
                    hc = decider.predict(extract_features(hal), dim)
                else:
                    lc, _ = CostModel(loc, calibration=calibration).best(
                        dim, space, H=heads)
                    hc, _ = CostModel(hal, calibration=calibration).best(
                        dim, space, H=heads)
                self.overlap_configs.append((lc, hc))
                loc_pcsrs.append(build_pcsr(loc.indptr, loc.indices,
                                            loc.data, loc.n_rows,
                                            loc.n_cols, lc))
                halo_pcsrs.append(build_pcsr(hal.indptr, hal.indices,
                                             hal.data, hal.n_rows,
                                             hal.n_cols, hc))
            self._loc = pack_shards(loc_pcsrs)
            self._halo_pack = pack_shards(halo_pcsrs)
            if _obs_trace.trace_enabled():
                # priced overlap decomposition per shard: the wire time
                # the schedule is trying to hide vs what stays exposed
                exch = halo_exchange_cost(self.halo.gathered_rows, dim)
                _obs_metrics.gauge("halo_exchange_priced_seconds").set(exch)
                for i, ((loc, hal), (lc, hc)) in enumerate(
                        zip(self._split_csrs, self.overlap_configs)):
                    tl = CostModel(loc, calibration=calibration).time(
                        dim, lc, H=heads)
                    th = CostModel(hal, calibration=calibration).time(
                        dim, hc, H=heads)
                    _obs_metrics.gauge("overlap_exposed_seconds").set(
                        overlap_exposed_cost(tl, th, exch), shard=i)
                    _obs_metrics.gauge("overlap_serialized_seconds").set(
                        tl + th + exch, shard=i)

        self._bwd_pack = None              # transpose PCSRs built on first
        self._bwd_split_pack = None        # backward only — forward-only /
        # GAT (engine-autodiff) use skips them
        self._send_idx = jnp.asarray(self.halo.send_idx)
        self._halo_src = jnp.asarray(self.halo.halo_src)

        # global ↔ padded-layout row maps
        g = np.arange(self.part.n_global, dtype=np.int64)
        pad_pos = self.part.pad_position(g)
        n_pad = self.part.n_parts * self.part.rows_pad
        pad_src = np.zeros(n_pad, np.int32)
        pad_valid = np.zeros(n_pad, bool)
        pad_src[pad_pos] = g
        pad_valid[pad_pos] = True
        self._pad_pos = jnp.asarray(pad_pos.astype(np.int32))
        self._pad_src = jnp.asarray(pad_src)
        self._pad_valid = jnp.asarray(pad_valid)

        self._spmm_fn = None               # built lazily (first call) so a
        self._gat_fns: dict = {}           # host-side plan needs no devices
        self._gat_packs: dict = {}         # per-H head-tiled GAT packs
        self._fused_fns: dict = {}         # per-activation fused programs
        self._fused_bwd_fns: dict = {}     # per-activation fused backwards
        self._bwd_fn = None                # shared transpose-path shard_map

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_partition_mesh
            self._mesh = make_partition_mesh(self.part.n_parts)
        return self._mesh

    @property
    def _bwd(self) -> PackedShards:
        """Transpose PCSRs of the full per-shard matrices (lazy)."""
        if self._bwd_pack is None:
            bwd = []
            for s, cfg in zip(self.part.shards, self.configs):
                t = s.csr.transpose()      # (ext_cols, rows_pad)
                bwd.append(build_pcsr(t.indptr, t.indices, t.data,
                                      t.n_rows, t.n_cols, cfg))
            self._bwd_pack = pack_shards(bwd)
        return self._bwd_pack

    @property
    def _bwd_split(self):
        """Transpose PCSRs of the local/halo sub-matrices (overlap mode,
        lazy): ``A_locᵀ`` is (rows_pad, rows_pad), ``A_haloᵀ`` is
        (halo_pad, rows_pad) — its output IS the halo gradient block."""
        if self._bwd_split_pack is None:
            loc_t, halo_t = [], []
            for (loc, hal), (lc, hc) in zip(self._split_csrs,
                                            self.overlap_configs):
                lt = loc.transpose()
                ht = hal.transpose()
                loc_t.append(build_pcsr(lt.indptr, lt.indices, lt.data,
                                        lt.n_rows, lt.n_cols, lc))
                halo_t.append(build_pcsr(ht.indptr, ht.indices, ht.data,
                                         ht.n_rows, ht.n_cols, hc))
            self._bwd_split_pack = (pack_shards(loc_t), pack_shards(halo_t))
        return self._bwd_split_pack

    def gat_pack(self, H: int):
        """Head-tiled covered steering pack for an ``H``-head GAT
        (cached per head count; Pallas backend only).  H = 1 reuses the
        graph's own forward pack — the covered arrays are identical."""
        if H not in self._gat_packs:
            self._gat_packs[H] = build_gat_pack(
                self._fwd.pcsrs, H, fwd=self._fwd if H == 1 else None)
        return self._gat_packs[H]

    # ---------------------------------------------------------- layout
    def pad(self, x):
        """(n_global, d) → (P·rows_pad, d) padded mesh layout."""
        x = jnp.asarray(x)
        return jnp.where(self._pad_valid[:, None],
                         jnp.take(x, self._pad_src, axis=0), 0)

    def unpad(self, x):
        """(P·rows_pad, d) padded mesh layout → (n_global, d)."""
        return jnp.take(x, self._pad_pos, axis=0)

    def pad_heads(self, x):
        """(H, n_global, d) head stack → (P·rows_pad, H·d) merged padded
        mesh layout (heads ride the feature axis so every mesh operand
        stays rank-2; branches split them back out)."""
        x = jnp.asarray(x)
        return self.pad(jnp.transpose(x, (1, 0, 2)).reshape(x.shape[1], -1))

    def unpad_heads(self, x, H: int):
        """(P·rows_pad, H·d) merged padded layout → (H, n_global, d)."""
        y = self.unpad(x)
        return y.reshape(y.shape[0], H, -1).transpose(1, 0, 2)

    # -------------------------------------------------------- dynamics
    def refresh(self, new_csr: CSRMatrix, *, threshold=None):
        """Swap in a mutated adjacency with **per-shard re-pack**: only
        shards whose local subgraph changed rebuild their steering pack
        (and re-pick their config when their feature snapshot drifted
        past ``threshold``); unchanged shards keep their PCSR objects
        and the SPMD program structure is untouched.  Returns a
        ``repro.dynamic.ShardRefreshReport``.  See
        ``repro.dynamic.refresh_dist_graph`` / docs/DYNAMIC.md."""
        from repro.dynamic.dist import refresh_dist_graph
        return refresh_dist_graph(self, new_csr, threshold=threshold)

    # ------------------------------------------------------- operators
    def spmm(self, B):
        """``C = A·B`` distributed; ``(n_global, d)`` in and out.

        A ``custom_vjp``: the backward runs the per-shard transpose PCSR
        and scatters halo gradients home (``overlap=True`` additionally
        hides the forward gather behind the local sub-SpMM and the
        backward ``psum_scatter`` behind the local transpose SpMM)."""
        if self._spmm_fn is None:
            self._spmm_fn = _build_dist_spmm(self)
        return self._spmm_fn(B)

    __call__ = spmm

    def fused(self, B, scale=None, bias=None, activation: str = "none"):
        """Epilogue-fused distributed aggregation
        ``act(scale ⊙ (A·B) + bias)`` — scale/bias/activation are applied
        *per shard inside the SPMD program* (in-kernel on the Pallas
        backend, XLA-fused into the branch on the engine backend), so no
        separate global elementwise pass follows the halo'd SpMM.  Under
        ``overlap=True`` the epilogue applies per shard after the
        local+halo add (XLA-fused; the in-kernel epilogue is traded for
        the hidden gather).  Differentiable in ``B`` and ``bias`` —
        the backward is ONE shard_map program returning ``dB`` and a
        ``psum``-replicated ``dbias``; ``scale`` (degree data) is a
        constant."""
        if activation not in self._fused_fns:
            self._fused_fns[activation] = _build_dist_fused_spmm(
                self, activation=activation)
        n, d = self.part.n_global, jnp.shape(B)[-1]
        scale = jnp.ones(n, jnp.float32) if scale is None \
            else jnp.asarray(scale)
        bias_arr = jnp.zeros(d, jnp.float32) if bias is None \
            else jnp.asarray(bias)
        out = self._fused_fns[activation](B, scale, bias_arr)
        return out

    def _fused_bwd(self, activation: str):
        """The fused backward SPMD program (cached per activation):
        ``(out, scale, dOut) -> (dB, dbias)`` with the ``dbias``
        reduction folded into the transpose shard_map as a ``psum``."""
        if activation not in self._fused_bwd_fns:
            self._fused_bwd_fns[activation] = _build_dist_fused_bwd(
                self, activation=activation)
        return self._fused_bwd_fns[activation]

    def gat_message(self, Q, K, Vf, *, slope: float = 0.2):
        """Distributed GAT attention message.

        ``(n, d)`` operands run single-head; ``(H, n, d)`` stacks batch
        every head through the per-shard head-tiled steering arrays in
        ONE SPMD program — on the Pallas backend that is exactly two
        kernels per shard forward (fused SDDMM→softmax-stats + prologue
        SpMM) and an all-Pallas flash-recompute backward with halo
        gradient scatter-back; the engine backend is natively
        differentiable.  See ``repro.dist.gat`` for the pipeline."""
        Q, K, Vf = (jnp.asarray(x) for x in (Q, K, Vf))
        multi = Q.ndim == 3
        H = Q.shape[0] if multi else 1
        key = (slope, H)
        if key not in self._gat_fns:
            self._gat_fns[key] = build_dist_gat(self, slope=slope, H=H)
        fn = self._gat_fns[key]
        if multi:
            return fn(Q, K, Vf)
        return fn(Q[None], K[None], Vf[None])[0]


# ------------------------------------------------------ transpose core
def _bwd_core(g: DistGraph):
    """The per-shard transpose-path core ``dc -> dB_local`` (halo
    gradient block scattered home), shared by the plain and the
    epilogue-fused distributed backwards.

    Non-overlap graphs run one transpose SpMM over the extended column
    space and scatter its halo block back.  Overlap graphs run the split
    form: the halo-side transpose SpMM first, whose ``psum_scatter``
    collective then overlaps with the local transpose SpMM (no data
    dependency between them).  Returns ``(core, ops)`` where ``ops`` are
    the mesh-sharded operand arrays the enclosing shard_map must be
    handed after the gradient operand(s)."""
    rows_pad = g.part.rows_pad
    n_parts, max_send = g.halo.n_parts, g.halo.max_send

    def scatter(d_halo, sidx, hsrc):
        return halo_scatter_back(d_halo, sidx, hsrc, n_parts=n_parts,
                                 max_send=max_send, rows_pad=rows_pad,
                                 axis_name=AXIS)

    if not g.overlap:
        branches = [_spmm_branch(p, n_out=g.part.ext_cols,
                                 backend=g.backend, interpret=g.interpret)
                    for p in g._bwd.pcsrs]

        def core(dc, colidx, lrow, trow, init, fini, vals, sidx, hsrc):
            i = jax.lax.axis_index(AXIS)
            d_ext = jax.lax.switch(i, branches, colidx[0], lrow[0],
                                   trow[0], init[0], fini[0], vals[0], dc)
            back = scatter(d_ext[rows_pad:], sidx[0], hsrc[0])
            return d_ext[:rows_pad] + back

        return core, (*g._bwd.arrays, g._send_idx, g._halo_src)

    loc_t, halo_t = g._bwd_split
    loc_branches = [_spmm_branch(p, n_out=rows_pad, backend=g.backend,
                                 interpret=g.interpret)
                    for p in loc_t.pcsrs]
    halo_branches = [_spmm_branch(p, n_out=g.part.halo_pad,
                                  backend=g.backend, interpret=g.interpret)
                     for p in halo_t.pcsrs]

    def core(dc, lc, ll, lt, li, lf, lv, hc, hl, ht, hi, hf, hv,
             sidx, hsrc):
        i = jax.lax.axis_index(AXIS)
        # halo-side transpose first: its scatter-back collective then
        # overlaps with the local transpose SpMM (no data dependency)
        d_halo = jax.lax.switch(i, halo_branches, hc[0], hl[0], ht[0],
                                hi[0], hf[0], hv[0], dc)
        back = scatter(d_halo, sidx[0], hsrc[0])
        d_loc = jax.lax.switch(i, loc_branches, lc[0], ll[0], lt[0],
                               li[0], lf[0], lv[0], dc)
        return d_loc + back

    return core, (*loc_t.arrays, *halo_t.arrays, g._send_idx, g._halo_src)


def _dist_bwd_transpose(g: DistGraph):
    """The transpose-path backward ``dB = Aᵀ·dC`` with halo scatter-back,
    built lazily on the first backward trace (forward-only use never
    builds the transpose PCSRs) and shared between the plain and the
    epilogue-fused distributed SpMM."""
    if g._bwd_fn is None:
        core, ops = _bwd_core(g)
        sm = shard_map_2d(core, g.mesh, 1 + len(ops))

        def run(dC):
            return g.unpad(sm(g.pad(dC), *ops))

        g._bwd_fn = jax.jit(run)   # cache the SPMD trace across steps
    return g._bwd_fn


def _build_dist_fused_bwd(g: DistGraph, *, activation: str):
    """The fused-epilogue backward as ONE SPMD program: per shard

        dpre  = dOut ⊙ act'(out)
        dbias = psum(Σ_local-rows dpre)        (replicated output)
        dB    = transpose-core(scale ⊙ dpre)   (halo block scattered home)

    The ``dbias`` reduction is an in-program ``psum`` down the mesh axis
    — NOT a global reduce outside the SPMD program — so the whole fused
    backward lives in one shard_map whatever the mesh size."""
    core, ops = _bwd_core(g)

    def body(dout, out, sc, *rest):
        dpre = epilogue_grad(out, dout, activation)
        dbias = jax.lax.psum(jnp.sum(dpre, axis=0), AXIS)
        return core(dpre * sc, *rest), dbias

    out_specs = (PartitionSpec(AXIS, None), PartitionSpec(None))
    sm = shard_map_2d(body, g.mesh, 3 + len(ops), out_specs=out_specs)

    @jax.jit
    def run(out, scale, dOut):
        dB, dbias = sm(g.pad(dOut), g.pad(out), g.pad(scale[:, None]),
                       *ops)
        return g.unpad(dB), dbias

    return run


# ------------------------------------------------------- forward paths
def _build_dist_spmm(g: DistGraph):
    """The ``custom_vjp`` distributed SpMM closed over one DistGraph."""
    if g.overlap:
        run_fwd = _build_overlap_fwd(g)
    else:
        fwd_branches = [_spmm_branch(p, n_out=g.part.rows_pad,
                                     backend=g.backend,
                                     interpret=g.interpret)
                        for p in g._fwd.pcsrs]

        def fwd_body(b, colidx, lrow, trow, init, fini, vals, sidx, hsrc):
            halo = halo_exchange(b, sidx[0], hsrc[0], axis_name=AXIS)
            b_ext = jnp.concatenate([b, halo], axis=0)
            i = jax.lax.axis_index(AXIS)
            return jax.lax.switch(i, fwd_branches, colidx[0], lrow[0],
                                  trow[0], init[0], fini[0], vals[0],
                                  b_ext)

        fwd_sm = shard_map_2d(fwd_body, g.mesh, 9)

        def run_fwd(B):
            out = fwd_sm(g.pad(B), *g._fwd.arrays,
                         g._send_idx, g._halo_src)
            return g.unpad(out)

    @jax.custom_vjp
    def f(B):
        return run_fwd(B)

    def f_fwd(B):
        return run_fwd(B), None

    def f_bwd(_, dC):
        return (_dist_bwd_transpose(g)(dC),)

    f.defvjp(f_fwd, f_bwd)
    return jax.jit(f)          # cache the SPMD trace across training steps


def _build_overlap_fwd(g: DistGraph, *, epilogue: bool = False,
                       activation: str = "none"):
    """The overlap forward: ``A_p·B_ext = A_loc·B_loc + A_halo·halo``.

    The ``all_gather`` is issued first; the local sub-SpMM takes only the
    shard's own feature block, so the XLA latency-hiding scheduler runs
    it concurrently with the collective — the gather's wire time hides
    behind local compute and only the (much smaller) halo sub-SpMM waits
    for the landed rows.  With ``epilogue=True`` scale/bias/activation
    apply per shard after the add (XLA-fused; an in-kernel epilogue
    would force the two partial SpMMs to accumulate in one kernel)."""
    loc_branches = [_spmm_branch(p, n_out=g.part.rows_pad,
                                 backend=g.backend, interpret=g.interpret)
                    for p in g._loc.pcsrs]
    halo_branches = [_spmm_branch(p, n_out=g.part.rows_pad,
                                  backend=g.backend, interpret=g.interpret)
                     for p in g._halo_pack.pcsrs]

    def body(b, lc, ll, lt, li, lf, lv, hc, hl, ht, hi, hf, hv,
             sidx, hsrc, *ep):
        halo = halo_exchange(b, sidx[0], hsrc[0], axis_name=AXIS)
        i = jax.lax.axis_index(AXIS)
        out_loc = jax.lax.switch(i, loc_branches, lc[0], ll[0], lt[0],
                                 li[0], lf[0], lv[0], b)
        out_halo = jax.lax.switch(i, halo_branches, hc[0], hl[0], ht[0],
                                  hi[0], hf[0], hv[0], halo)
        out = out_loc + out_halo
        if epilogue:
            out = apply_epilogue(out, ep[0][:, 0], ep[1][0], activation)
        return out

    n_in = 15 + (2 if epilogue else 0)
    replicated = (16,) if epilogue else ()
    sm = shard_map_2d(body, g.mesh, n_in, replicated=replicated)
    ops = (*g._loc.arrays, *g._halo_pack.arrays, g._send_idx, g._halo_src)

    def run_fwd(B, *ep):
        return g.unpad(sm(g.pad(B), *ops, *ep))

    return run_fwd


def _build_dist_fused_spmm(g: DistGraph, *, activation: str):
    """Epilogue-fused distributed SpMM: one SPMD program whose per-shard
    branches apply scale/bias/activation where the output is produced —
    in-kernel (Pallas) or XLA-fused into the branch (engine) — so the
    fused distributed GCN layer runs no global elementwise pass after the
    halo'd SpMM.  A ``custom_vjp`` over (B, bias): the backward is one
    shard_map program computing ``dB`` through the shared transpose path
    on ``scale ⊙ (dOut ⊙ act'(out))`` with the ``dbias`` reduction folded
    in as a ``psum`` (see ``_build_dist_fused_bwd``)."""
    if g.overlap:
        overlap_fwd = _build_overlap_fwd(g, epilogue=True,
                                         activation=activation)

        @jax.jit
        def run_fwd(B, scale, bias):
            return overlap_fwd(B, g.pad(scale[:, None]), bias[None, :])
    else:
        rows_pad = g.part.rows_pad
        branches = [_spmm_branch(p, n_out=rows_pad, backend=g.backend,
                                 interpret=g.interpret, epilogue=True,
                                 activation=activation)
                    for p in g._fwd.pcsrs]

        def body(b, colidx, lrow, trow, init, fini, vals, sidx, hsrc,
                 sc, bi):
            halo = halo_exchange(b, sidx[0], hsrc[0], axis_name=AXIS)
            b_ext = jnp.concatenate([b, halo], axis=0)
            i = jax.lax.axis_index(AXIS)
            return jax.lax.switch(i, branches, colidx[0], lrow[0],
                                  trow[0], init[0], fini[0], vals[0],
                                  b_ext, sc, bi)

        sm = shard_map_2d(body, g.mesh, 11, replicated=(10,))

        @jax.jit                       # cache the SPMD trace across steps;
        def run_fwd(B, scale, bias):   # the custom_vjp wrapper stays unjitted
            out = sm(g.pad(B), *g._fwd.arrays, g._send_idx, g._halo_src,
                     g.pad(scale[:, None]), bias[None, :])
            return g.unpad(out)

    @jax.custom_vjp
    def f(B, scale, bias):
        return run_fwd(B, scale, bias)

    def f_fwd(B, scale, bias):
        out = run_fwd(B, scale, bias)
        return out, (out, scale)

    def f_bwd(res, dOut):
        out, scale = res
        dB, dbias = g._fused_bwd(activation)(out, scale, dOut)
        # scale is graph data (degree norms), not a trained parameter
        return dB, jnp.zeros_like(scale), dbias

    f.defvjp(f_fwd, f_bwd)
    return f


# ------------------------------------------------------ functional API
def dist_spmm(graph: DistGraph, B):
    """``C = A·B`` over a partitioned graph; ``(n, d)`` global in and
    out.  The backward is the explicit per-shard transpose path with halo
    gradient scatter-back (see ``DistGraph.spmm``)."""
    return graph.spmm(B)


def dist_gat_message(graph: DistGraph, Q, K, Vf, *, slope: float = 0.2):
    """Distributed SDDMM → LeakyReLU → edge softmax → SpMM message.

    ``(n, d)`` operands run single-head; ``(H, n, d)`` stacks run every
    head through one head-tiled SPMD program.  On the Pallas backend the
    forward is exactly two kernels per shard and the backward is the
    all-Pallas flash recompute (``repro.dist.gat``); on the engine
    backend the program is natively differentiable."""
    return graph.gat_message(Q, K, Vf, slope=slope)
