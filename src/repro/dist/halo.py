"""Halo feature exchange over the partition mesh.

A shard's SpMM/SDDMM gathers source-node rows it does not own.  Rather
than all-gathering the full feature matrix (O(n·d) per device), the
exchange is *compacted* on the host once per partition:

* ``send_idx[q]`` — the local row positions shard ``q`` contributes: the
  sorted union of every other shard's halo requests that ``q`` owns;
* ``halo_src[p]`` — for each of shard ``p``'s halo columns, the flat
  position of that row inside the all-gathered send buffer
  ``(P · max_send, d)``.

One ``all_gather`` of the packed send buffers per layer then serves both
SpMM and SDDMM on that shard (the gathered rows are concatenated after
the local block to form the extended column space the local PCSR
indexes).  The reverse path — scattering halo *gradients* back to their
owners — is the exact transpose: scatter-add into the flat buffer, a
``psum_scatter`` down the mesh axis, and a local scatter-add at
``send_idx``.

Both directions are plain JAX inside ``shard_map`` bodies, so autodiff
of a forward exchange materializes the reverse exchange automatically;
``halo_scatter_back`` exists for explicit ``custom_vjp`` backwards — the
distributed SpMM's transpose path and the distributed GAT backward,
which scatters the dK/dVf halo blocks home in ONE joint collective (the
gradients travel concatenated along the feature axis, exactly like the
joint K/Vf forward exchange).  Under ``DistGraph(overlap=True)`` the
same two primitives are issued *before* the independent local compute so
the scheduler hides their wire time (docs/DISTRIBUTED.md §Overlap).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.obs import metrics as _obs_metrics

from .partition import RowPartition


@dataclass
class HaloSpec:
    """Host-side compact exchange plan (numpy; stacked per shard)."""

    n_parts: int
    max_send: int            # padded send-buffer rows per shard (≥ 1)
    max_halo: int            # padded halo width per shard (= part.halo_pad)
    send_idx: np.ndarray     # (P, max_send) int32 local rows to contribute
    n_send: np.ndarray       # (P,) true send counts
    halo_src: np.ndarray     # (P, max_halo) int32 flat gathered positions
    n_halo: np.ndarray       # (P,) true halo counts

    @property
    def gathered_rows(self) -> int:
        return self.n_parts * self.max_send


def build_halo(part: RowPartition) -> HaloSpec:
    """Compact send/recv maps from the partition's halo column lists."""
    P = part.n_parts
    requests = [s.halo_global for s in part.shards]
    all_req = (np.unique(np.concatenate(requests))
               if any(r.size for r in requests)
               else np.zeros(0, np.int64))
    owners = part.owner(all_req)
    send_rows = [all_req[owners == q] for q in range(P)]  # sorted global ids
    max_send = max(1, max((s.shape[0] for s in send_rows), default=1))

    send_idx = np.zeros((P, max_send), np.int32)
    n_send = np.zeros(P, np.int64)
    for q in range(P):
        k = send_rows[q].shape[0]
        send_idx[q, :k] = send_rows[q] - part.starts[q]   # local positions
        n_send[q] = k

    halo_src = np.zeros((P, part.halo_pad), np.int32)
    n_halo = np.zeros(P, np.int64)
    for p in range(P):
        halo = requests[p]
        if halo.size:
            own = part.owner(halo)
            pos = np.empty(halo.shape[0], np.int64)
            for q in range(P):
                sel = own == q
                if sel.any():
                    # rank of each requested row in its owner's send list
                    pos[sel] = (q * max_send
                                + np.searchsorted(send_rows[q], halo[sel]))
            halo_src[p, :halo.shape[0]] = pos
        n_halo[p] = halo.shape[0]
    return HaloSpec(P, max_send, part.halo_pad, send_idx, n_send,
                    halo_src, n_halo)


def halo_exchange(b_loc, send_idx_loc, halo_src_loc, *,
                  axis_name: str = "parts"):
    """Inside-``shard_map`` forward exchange: local features → halo rows.

    b_loc (rows_pad, d); send_idx_loc (max_send,); halo_src_loc
    (max_halo,) → (max_halo, d) rows of remote features, ready to
    concatenate after the local block.
    """
    import jax
    import jax.numpy as jnp

    send = jnp.take(b_loc, send_idx_loc, axis=0)
    full = jax.lax.all_gather(send, axis_name, axis=0, tiled=True)
    # observed at trace time (once per compiled program, not per step):
    # bytes of the all-gathered send buffer every shard receives
    _obs_metrics.counter("halo_exchange_bytes_total").inc(
        int(np.prod(full.shape)) * full.dtype.itemsize, direction="gather")
    return jnp.take(full, halo_src_loc, axis=0)


def halo_scatter_back(d_halo, send_idx_loc, halo_src_loc, *,
                      n_parts: int, max_send: int, rows_pad: int,
                      axis_name: str = "parts"):
    """Inside-``shard_map`` reverse exchange: halo gradients → owners.

    The transpose of ``halo_exchange``: d_halo (max_halo, d) scatters
    into the flat gathered layout, ``psum_scatter`` hands every shard the
    summed block for its own send rows, and a local scatter-add folds
    them into a (rows_pad, d) gradient.  Padded halo entries carry zero
    gradient (their extended columns have no edges) so their aliased
    flat position 0 receives only zeros.
    """
    import jax
    import jax.numpy as jnp

    d = d_halo.shape[-1]
    _obs_metrics.counter("halo_exchange_bytes_total").inc(
        int(n_parts * max_send * d) * d_halo.dtype.itemsize,
        direction="scatter")
    buf = jnp.zeros((n_parts * max_send, d), d_halo.dtype)
    buf = buf.at[halo_src_loc].add(d_halo)
    own = jax.lax.psum_scatter(buf, axis_name, scatter_dimension=0,
                               tiled=True)                 # (max_send, d)
    return jnp.zeros((rows_pad, d), d_halo.dtype).at[send_idx_loc].add(own)
