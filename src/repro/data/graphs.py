"""Synthetic graph corpus with controlled input diversity.

The paper evaluates on 202 SNAP/DIMACS10 matrices spanning data locality,
degree distribution, and size (§6.2: n 1e3–7.7e6, ρ 2.7e-7–0.025,
CV 0.006–58).  Offline we reproduce that *diversity* with deterministic
generators that target each axis:

  rmat        — power-law, high CV (social-network analogue, sx-*)
  ba          — Barabási-Albert preferential attachment (power-law)
  er          — Erdős–Rényi (Poisson degrees, balanced: road/traffic-like)
  grid2d      — lattice (extreme locality, low constant degree: DIMACS road)
  sbm         — stochastic block model (community structure: coPapers-*)
  kregular    — random regular (perfectly balanced degrees)

Each generator takes ``shuffle=True`` to destroy ID locality (the
reordering/blocking ablations toggle it).  All graphs are undirected
(symmetrized), weighted 1.0, canonical CSR.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.sparse import CSRMatrix


def _finish(src, dst, n, shuffle, seed) -> CSRMatrix:
    mask = src != dst                      # drop self loops
    src, dst = src[mask], dst[mask]
    if shuffle:
        perm = np.random.default_rng(seed + 7).permutation(n)
        src, dst = perm[src], perm[dst]
    csr = CSRMatrix.from_edges(src, dst, n, symmetrize=True)
    # binarize (duplicate edges summed by from_coo → clamp back to 1.0)
    csr.data = np.ones_like(csr.data)
    return csr


def rmat(n_log2: int, avg_deg: int, seed: int = 0, shuffle: bool = False,
         a=0.57, b=0.19, c=0.19) -> CSRMatrix:
    n = 1 << n_log2
    ne = n * avg_deg // 2
    rng = np.random.default_rng(seed)
    src = np.zeros(ne, np.int64)
    dst = np.zeros(ne, np.int64)
    for lvl in range(n_log2):
        r = rng.random(ne)
        go_s = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        go_d = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src = src * 2 + go_s
        dst = dst * 2 + go_d
    return _finish(src, dst, n, shuffle, seed)


def ba(n: int, m: int, seed: int = 0, shuffle: bool = False) -> CSRMatrix:
    """Barabási–Albert via the repeated-edge-endpoint trick (vectorized)."""
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    targets = np.arange(m, dtype=np.int64)
    repeated = list(range(m))
    for v in range(m, n):
        src_l.append(np.full(m, v, np.int64))
        dst_l.append(targets.copy())
        repeated.extend(targets.tolist())
        repeated.extend([v] * m)
        pick = rng.integers(0, len(repeated), m)
        targets = np.array([repeated[p] for p in pick], np.int64)
    return _finish(np.concatenate(src_l), np.concatenate(dst_l), n,
                   shuffle, seed)


def er(n: int, avg_deg: float, seed: int = 0, shuffle: bool = False) -> CSRMatrix:
    rng = np.random.default_rng(seed)
    ne = int(n * avg_deg / 2)
    src = rng.integers(0, n, ne)
    dst = rng.integers(0, n, ne)
    return _finish(src, dst, n, shuffle, seed)


def grid2d(side: int, seed: int = 0, shuffle: bool = False) -> CSRMatrix:
    n = side * side
    idx = np.arange(n).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()])
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()])
    e = np.concatenate([right, down], axis=1)
    return _finish(e[0], e[1], n, shuffle, seed)


def sbm(n_blocks: int, block_size: int, p_in: float, p_out_deg: float,
        seed: int = 0, shuffle: bool = False) -> CSRMatrix:
    """Stochastic block model: dense communities + sparse global edges."""
    rng = np.random.default_rng(seed)
    n = n_blocks * block_size
    src_l, dst_l = [], []
    ne_in = int(p_in * block_size * (block_size - 1) / 2)
    for b in range(n_blocks):
        s = rng.integers(0, block_size, ne_in) + b * block_size
        d = rng.integers(0, block_size, ne_in) + b * block_size
        src_l.append(s)
        dst_l.append(d)
    ne_out = int(n * p_out_deg / 2)
    src_l.append(rng.integers(0, n, ne_out))
    dst_l.append(rng.integers(0, n, ne_out))
    return _finish(np.concatenate(src_l), np.concatenate(dst_l), n,
                   shuffle, seed)


def clones(n_base: int, deg: int, clone: int = 2, mutate: float = 0.15,
           seed: int = 0, shuffle: bool = False,
           directed: bool = True) -> CSRMatrix:
    """Co-citation-style graph (coPapers analogue): consecutive ``clone``
    rows share most of their neighbor set — the structure that vectorized
    blocking (V=2) exploits (low PR_2).  Directed by default: symmetrizing
    scatters the clone structure across reverse rows."""
    rng = np.random.default_rng(seed)
    n = n_base * clone
    src_l, dst_l = [], []
    for c in range(clone):
        base_dst = rng.integers(0, n, (n_base, deg))
        if c == 0:
            shared = base_dst
        else:
            mut = rng.random((n_base, deg)) < mutate
            base_dst = np.where(mut, base_dst, shared)
        rows = (np.arange(n_base) * clone + c)[:, None]
        src_l.append(np.broadcast_to(rows, base_dst.shape).ravel())
        dst_l.append(base_dst.ravel())
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    if directed:
        mask = src != dst
        src, dst = src[mask], dst[mask]
        if shuffle:
            perm = np.random.default_rng(seed + 7).permutation(n)
            src, dst = perm[src], perm[dst]
        csr = CSRMatrix.from_coo(src, dst, np.ones(src.shape[0], np.float32),
                                 n, n)
        csr.data = np.ones_like(csr.data)
        return csr
    return _finish(src, dst, n, shuffle, seed)


def kregular(n: int, k: int, seed: int = 0, shuffle: bool = False) -> CSRMatrix:
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    for _ in range(k // 2):
        perm = rng.permutation(n)
        src_l.append(perm)
        dst_l.append(np.roll(perm, 1))
    return _finish(np.concatenate(src_l), np.concatenate(dst_l), n,
                   shuffle, seed)


# --------------------------------------------------------------- serving
def sample_khop(csr: CSRMatrix, seeds, fanouts, *, seed: int = 0) -> np.ndarray:
    """Seeded k-hop neighborhood with per-hop fanout caps (GraphSAGE-style).

    Hop ``i`` expands the current frontier by at most ``fanouts[i]``
    neighbors per frontier node, sampled *without replacement* via a
    vectorized sort-by-(node, random) + positional mask — no Python loop
    over nodes.  Deterministic in ``seed``: the serving tier's replay
    soak relies on same-seed → same node set.  Returns the sorted unique
    node ids of the sampled neighborhood (seeds always included, even
    seeds with empty neighborhoods).
    """
    rng = np.random.default_rng(seed)
    visited = np.unique(np.asarray(seeds, np.int64))
    if visited.size and (visited[0] < 0 or visited[-1] >= csr.n_rows):
        raise ValueError("seed node id out of range")
    frontier = visited
    for fan in fanouts:
        if frontier.size == 0 or fan <= 0:
            break
        starts = csr.indptr[frontier]
        counts = csr.indptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        seg_off = np.cumsum(counts) - counts
        flat = np.arange(total, dtype=np.int64)
        pos = flat - np.repeat(seg_off, counts) + np.repeat(starts, counts)
        nbrs = csr.indices[pos]
        seg = np.repeat(np.arange(frontier.size, dtype=np.int64), counts)
        order = np.lexsort((rng.random(total), seg))   # shuffle within node
        rank = flat - np.repeat(seg_off, counts)       # 0.. within node
        picked = nbrs[order][rank < fan]               # first ``fan`` each
        new = np.setdiff1d(np.unique(picked), visited, assume_unique=True)
        visited = np.union1d(visited, new)
        frontier = new
    return visited


def extract_subgraph(csr: CSRMatrix, nodes) -> CSRMatrix:
    """Induced subgraph on ``nodes`` with local id relabeling.

    ``nodes`` must be sorted unique global ids (what ``sample_khop``
    returns); local id ``i`` is the position of ``nodes[i]``.  Edges with
    either endpoint outside ``nodes`` are dropped.  Vectorized CSR
    range-gather — no per-node Python loop.
    """
    nodes = np.asarray(nodes, np.int64)
    m = int(nodes.size)
    if m == 0:
        return CSRMatrix(np.zeros(1, np.int64), np.zeros(0, np.int64),
                         np.zeros(0, np.float32), 0, 0)
    lookup = np.full(csr.n_cols, -1, np.int64)
    lookup[nodes] = np.arange(m, dtype=np.int64)
    starts = csr.indptr[nodes]
    counts = csr.indptr[nodes + 1] - starts
    total = int(counts.sum())
    seg_off = np.cumsum(counts) - counts
    flat = np.arange(total, dtype=np.int64)
    pos = flat - np.repeat(seg_off, counts) + np.repeat(starts, counts)
    cols_l = lookup[csr.indices[pos]]
    rows_l = np.repeat(np.arange(m, dtype=np.int64), counts)
    keep = cols_l >= 0
    return CSRMatrix.from_coo(rows_l[keep], cols_l[keep],
                              csr.data[pos][keep], m, m,
                              sum_duplicates=False)


@dataclass
class GraphSpec:
    name: str
    csr: CSRMatrix
    family: str


def corpus(scale: str = "small") -> list[GraphSpec]:
    """Deterministic graph corpus. ``small`` ≈ unit tests / CI;
    ``bench`` ≈ decider training + paper-table benchmarks; ``skewed`` ≈
    degree-skew stressors (high-CV power-law / co-citation graphs, where
    the balanced ``B`` chunk schedule should win) plus uniform-degree
    controls (where it should NOT be selected) — the corpus behind
    ``benchmarks/bench_spmm.py`` and the balanced-scheduling tests;
    ``large`` ≈ the calibration / adaptivity-at-scale tier (bigger
    rmat/ba/sbm plus ``clones`` skew): graphs big enough that config
    choice moves wall-clock by integer factors, so priced-vs-measured
    rank correlation on it is a meaningful claim — opt-in only (never
    generated in tier-1 CI)."""
    out = []

    def add(name, family, g):
        out.append(GraphSpec(name, g, family))

    if scale == "large":
        add("rmat16", "powerlaw", rmat(16, 8, seed=21))
        add("rmat17", "powerlaw", rmat(17, 6, seed=22))
        add("rmat16_sh", "powerlaw", rmat(16, 8, seed=21, shuffle=True))
        add("ba100k", "powerlaw", ba(100_000, 4, seed=23))
        add("sbm64x1k", "community", sbm(64, 1024, 0.02, 1.0, seed=24))
        add("sbm128x512", "community", sbm(128, 512, 0.04, 1.0, seed=25))
        add("clones50k", "cocitation", clones(50_000, 10, seed=26))
        add("clones25k_sh", "cocitation",
            clones(25_000, 12, seed=27, shuffle=True))
        add("er250k", "uniform", er(250_000, 6, seed=28))
        add("kreg150k", "uniform", kregular(150_000, 6, seed=29))
        add("grid512", "mesh", grid2d(512, seed=30))
        return out

    if scale == "skewed":
        add("rmat11", "powerlaw", rmat(11, 8, seed=11))
        add("rmat12", "powerlaw", rmat(12, 6, seed=12))
        add("ba2k", "powerlaw", ba(2000, 4, seed=13))
        add("ba4k", "powerlaw", ba(4000, 3, seed=14))
        add("clones1k", "cocitation", clones(1000, 10, seed=15))
        add("kreg2k", "uniform", kregular(2000, 8, seed=16))
        add("grid48", "mesh", grid2d(48, seed=17))
        return out

    if scale == "serve":
        # Serving-tier base graphs: big enough that sampled subgraphs
        # span several shape buckets, small enough for CI smoke streams.
        add("rmat13", "powerlaw", rmat(13, 8, seed=31))
        add("ba10k", "powerlaw", ba(10_000, 4, seed=32))
        add("sbm32x256", "community", sbm(32, 256, 0.12, 1.0, seed=33))
        add("er20k", "uniform", er(20_000, 6, seed=34))
        add("grid128", "mesh", grid2d(128, seed=35))
        return out

    if scale == "small":
        add("rmat10", "powerlaw", rmat(10, 8, seed=1))
        add("er1k", "uniform", er(1000, 8, seed=2))
        add("grid32", "mesh", grid2d(32, seed=3))
        add("sbm8x64", "community", sbm(8, 64, 0.3, 1.0, seed=4))
        add("ba1k", "powerlaw", ba(1000, 4, seed=5))
        return out

    sizes = [(12, 8), (13, 8), (14, 6), (15, 4), (16, 4)]
    seed = 0
    for lg, d in sizes:
        for sh in (False, True):
            tag = "_sh" if sh else ""
            add(f"rmat{lg}{tag}", "powerlaw", rmat(lg, d, seed, shuffle=sh))
            seed += 1
    for n, d in [(4000, 6), (16000, 8), (60000, 6), (150000, 4)]:
        for sh in (False, True):
            tag = "_sh" if sh else ""
            add(f"er{n}{tag}", "uniform", er(n, d, seed, shuffle=sh))
            seed += 1
    for side in (64, 128, 256, 384):
        for sh in (False, True):
            tag = "_sh" if sh else ""
            add(f"grid{side}{tag}", "mesh", grid2d(side, seed, shuffle=sh))
            seed += 1
    for nb, bs, pin in [(16, 128, 0.25), (32, 256, 0.12), (64, 512, 0.03),
                        (24, 1024, 0.015)]:
        for sh in (False, True):
            tag = "_sh" if sh else ""
            add(f"sbm{nb}x{bs}{tag}", "community",
                sbm(nb, bs, pin, 1.0, seed, shuffle=sh))
            seed += 1
    for n, k in [(8000, 8), (40000, 6), (120000, 4)]:
        add(f"kreg{n}", "uniform", kregular(n, k, seed))
        seed += 1
    for n, m in [(4000, 6), (20000, 5), (80000, 3)]:
        add(f"ba{n}", "powerlaw", ba(n, m, seed))
        seed += 1
    for nb, d in [(4000, 12), (16000, 10), (50000, 8)]:
        for sh in (False, True):
            tag = "_sh" if sh else ""
            add(f"clones{nb}{tag}", "cocitation",
                clones(nb, d, seed=seed, shuffle=sh))
            seed += 1
    return out
