"""Synthetic node-classification tasks (OGB-analogue for the GNN
experiments): community-structured graphs with class-dependent features —
learnable by message passing, deterministic per seed."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.sparse import CSRMatrix
from .graphs import sbm


@dataclass
class NodeTask:
    csr: CSRMatrix           # raw adjacency (unnormalized)
    features: np.ndarray     # (n, f) float32
    labels: np.ndarray       # (n,) int32
    train_mask: np.ndarray   # (n,) float32
    val_mask: np.ndarray
    n_classes: int


def community_task(n_blocks=8, block_size=128, feat_dim=16, p_in=0.15,
                   noise=1.0, train_frac=0.6, seed=0) -> NodeTask:
    rng = np.random.default_rng(seed)
    csr = sbm(n_blocks, block_size, p_in, 1.0, seed=seed)
    n = csr.n_rows
    labels = np.repeat(np.arange(n_blocks), block_size).astype(np.int32)
    centers = rng.standard_normal((n_blocks, feat_dim)).astype(np.float32)
    feats = centers[labels] + noise * rng.standard_normal(
        (n, feat_dim)).astype(np.float32)
    order = rng.permutation(n)
    n_train = int(train_frac * n)
    train_mask = np.zeros(n, np.float32)
    val_mask = np.zeros(n, np.float32)
    train_mask[order[:n_train]] = 1.0
    val_mask[order[n_train:]] = 1.0
    return NodeTask(csr, feats, labels, train_mask, val_mask, n_blocks)
