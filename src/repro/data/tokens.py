"""Deterministic, stateless synthetic LM data pipeline.

``batch_for_step(cfg, B, S, step)`` is a pure function of (seed, step):
restarts and elastic re-sizing never replay or skip data, which is the
fault-tolerance contract the checkpoint manager relies on (DESIGN.md §5).
The token stream is a noisy Markov chain, so small models show a clearly
decreasing loss (learnability sanity check for the e2e driver).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig


def _rng(seed: int, step: int):
    return np.random.Generator(np.random.Philox(key=seed, counter=step))


def batch_for_step(cfg: ArchConfig, batch: int, seq: int, step: int,
                   seed: int = 0, order: int = 64):
    rng = _rng(seed, step)
    V = cfg.vocab
    # Markov structure: next ≈ (prev · a + b) mod V with noise
    a = 31
    stream = np.zeros((batch, seq + 1), np.int64)
    stream[:, 0] = rng.integers(0, V, batch)
    noise = rng.random((batch, seq)) < 0.15
    rand = rng.integers(0, V, (batch, seq))
    for t in range(seq):
        nxt = (stream[:, t] * a + 7) % V
        stream[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
    out = {"tokens": stream[:, :-1].astype(np.int32),
           "labels": stream[:, 1:].astype(np.int32)}
    if cfg.family == "vlm":
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (batch, seq, cfg.d_model)).astype(np.float32)
    return out
