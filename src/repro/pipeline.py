"""The ParamSpMM three-phase workflow (paper Fig. 2):
configuration prediction → PCSR generation → computing engine.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .core import (CostModel, CSRMatrix, SpMMConfig, config_space,
                   extract_features)
from .core.decider import SpMMDecider
from .core.engine import ParamSpMMOperator
from .core.reorder import rabbit_reorder, apply_reorder


def pick_config(csr: CSRMatrix, dim: int, *,
                decider: Optional[SpMMDecider] = None,
                select: str = "model",
                op: str = "spmm",
                heads: int = 1) -> SpMMConfig:
    """Phase-1 configuration prediction, shared by every entry point.

    Resolution order: ``decider`` prediction > measured oracle search
    (``select="measured"``) > cost-model sweep over ``config_space``.
    ``ParamSpMM`` uses it per matrix; the serving tier
    (``repro.serve``) calls it once per shape bucket and amortizes the
    pick across every request the bucket ever serves.
    """
    if decider is not None:
        return decider.predict(extract_features(csr), dim)
    if select == "measured":
        # autotune for THIS host (the paper's oracle measures on the
        # deployment GPU; on CPU the TPU model mispredicts)
        from .core.autotune import oracle_search
        return oracle_search(csr, dim, mode="measured", reps=2).best_config
    config, _ = CostModel(csr).best(dim, config_space(dim), op=op, H=heads)
    return config


class ParamSpMM:
    """End-to-end adaptive SpMM for one sparse matrix and embedding dim.

    config resolution order: explicit ``config`` > ``decider`` prediction >
    cost-model oracle search (the fallback when no trained decider is at
    hand — e.g. first-run autotuning).

    ``op`` names the operator the config is chosen for ("spmm", "sddmm",
    or "gat" — the SDDMM+softmax+SpMM attention pair); it steers the
    cost-model search only, since the decider is SpMM-trained (per-operator
    decider labels remain a ROADMAP item).  ``heads`` prices multi-head
    attention's head-tiled grids (per-head dim, H× chunks/blocks), so a
    4-head layer can pick a different ⟨W,F,V,S⟩ than a single-head one.

    The wrapped operator exposes the fusion surface: ``p(B)`` is the plain
    SpMM, ``p.fused(B, scale=, bias=, activation=, residual=)`` the
    epilogue-fused aggregation (one kernel per GCN — or, via the residual
    addend, GIN — layer on the Pallas backend).
    """

    def __init__(self, csr: CSRMatrix, dim: int, *,
                 config: Optional[SpMMConfig] = None,
                 decider: Optional[SpMMDecider] = None,
                 reorder: bool = True,
                 backend: str = "engine",
                 interpret: bool = True,
                 build_transpose: bool = True,
                 select: str = "model",
                 op: str = "spmm",
                 heads: int = 1):
        self.perm = None
        if reorder:                       # paper §4.4: default preprocessing
            perm = rabbit_reorder(csr)
            cand = apply_reorder(csr, perm)
            # keep whichever ordering has better V=2 locality — reordering
            # an already well-ordered graph (e.g. co-citation clones) can
            # only hurt, and the metric is cheap (pcsr_stats)
            from .core import pcsr_stats
            pr_old = pcsr_stats(csr.indptr, csr.indices, csr.n_rows,
                                csr.n_cols, 2, 4).padding_ratio
            pr_new = pcsr_stats(cand.indptr, cand.indices, cand.n_rows,
                                cand.n_cols, 2, 4).padding_ratio
            if pr_new <= pr_old:
                self.perm = perm
                csr = cand
            else:
                self.perm = np.arange(csr.n_rows)
        self.csr = csr
        self.dim = dim
        if config is None:
            config = pick_config(csr, dim, decider=decider, select=select,
                                 op=op, heads=heads)
        self.config = config
        self.op = ParamSpMMOperator(csr, config, backend=backend,
                                    interpret=interpret,
                                    build_transpose=build_transpose)

    def __call__(self, B):
        return self.op(B)

    def fused(self, B, scale=None, bias=None, activation: str = "none",
              residual=None):
        """Epilogue-fused aggregation:
        act(scale ⊙ (A·B) + bias + residual)."""
        return self.op.fused(B, scale=scale, bias=bias,
                             activation=activation, residual=residual)
