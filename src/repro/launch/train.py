"""End-to-end training launcher with checkpoint/restart fault tolerance.

Runs real steps on whatever devices exist (CPU host mesh for the examples
and tests; the same code path drives the production mesh on TPU).  The
data pipeline is stateless-deterministic, checkpoints publish atomically
with an async writer, and ``--resume`` restarts from the latest snapshot —
kill the process at any step and relaunch to continue.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data.tokens import batch_for_step
from repro.models import lm
from repro.optim import (AdamWConfig, adamw_init, topk_compress_apply,
                         topk_compress_init)
from repro.optim.adamw import adamw_update
from .mesh import make_host_mesh


def build_step(cfg, opt_cfg, compress_frac=0.0):
    def step_fn(params, opt_state, err, batch):
        def loss_fn(p):
            return lm.train_loss(p, cfg, batch, chunk=256)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if compress_frac > 0:
            grads, err = topk_compress_apply(grads, err, compress_frac)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, err, loss

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", type=float, default=0.0,
                    help="top-k gradient compression fraction (0=off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, grad_clip=1.0)

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    opt_state = adamw_init(params)
    err = (topk_compress_init(params) if args.compress > 0
           else jnp.zeros((), jnp.float32))
    start_step = 0

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume:
            s, tree = mgr.restore()
            if s is not None:
                params, opt_state, err = tree
                start_step = s + 1
                print(f"resumed from step {s}", flush=True)

    # graceful preemption: checkpoint on SIGTERM, then exit cleanly
    stop = {"now": False}

    def _sigterm(*_):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _sigterm)

    step_fn = build_step(cfg, opt_cfg, args.compress)
    t0 = time.time()
    tokens_done = 0
    losses = []
    with mesh:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in batch_for_step(
                cfg, args.batch, args.seq, step, args.seed).items()}
            params, opt_state, err, loss = step_fn(params, opt_state, err,
                                                   batch)
            losses.append(float(loss))
            tokens_done += args.batch * args.seq
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"tok/s {tokens_done/max(dt,1e-9):,.0f}", flush=True)
            if mgr and (step % args.ckpt_every == 0 or stop["now"]
                        or step == args.steps - 1):
                mgr.save(step, (params, opt_state, err))
            if stop["now"]:
                print(f"SIGTERM: checkpointed at step {step}, exiting",
                      flush=True)
                mgr and mgr.wait()
                sys.exit(0)
    mgr and mgr.wait()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
    return losses


if __name__ == "__main__":
    train()
