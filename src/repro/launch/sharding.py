"""Role → PartitionSpec resolution (Megatron-style TP + DP/pod batch
sharding + EP for MoE), with deliberate divisibility fallbacks:

  col    — shard output features; fallback: contracting dim (row-parallel
           partial sums); fallback: replicate.  Handles odd-head archs
           (hymba 25H, whisper 6H) per DESIGN.md §5.
  row    — shard contracting dim; fallbacks symmetric.
  embed  — vocab-parallel embedding/unembedding.
  expert — shard the expert dim (EP); fallback: shard expert FFN features
           (granite-3b's 40 experts don't divide 16 → TP inside experts).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm
from .mesh import data_axes, model_axis_size


def _try_dims(shape, dims, parts, axis):
    """First dim in ``dims`` divisible by ``parts`` gets the model axis."""
    nd = len(shape)
    for d in dims:
        dd = d % nd
        if shape[dd] % parts == 0 and shape[dd] >= parts:
            spec = [None] * nd
            spec[dd] = axis
            return P(*spec)
    return P()


def role_pspec(role: str, shape, mesh) -> P:
    parts = model_axis_size(mesh)
    ax = "model"
    if parts <= 1:
        return P()
    nd = len(shape)
    if role == "embed":
        return _try_dims(shape, (0, 1), parts, ax)
    if role == "col":
        return _try_dims(shape, (-1, -2), parts, ax)
    if role == "row":
        return _try_dims(shape, (-2, -1), parts, ax)
    if role == "col_b":
        return _try_dims(shape, (-1,), parts, ax)
    if role == "expert_in":      # (L,E,D,ff): ff-parallel (shard_map MoE)
        return _try_dims(shape, (-1,), parts, ax)
    if role == "expert_down":    # (L,E,ff,D): ff is the contracting dim
        return _try_dims(shape, (-2,), parts, ax)
    if role == "expert":
        return _try_dims(shape, (1, -1, -2), parts, ax)
    return P()   # rep / rep_big


def param_pspecs(cfg: ArchConfig, mesh):
    return lm.map_defs(lambda d: role_pspec(d[1], d[0], mesh),
                       lm.model_defs(cfg))


def param_shardings(cfg: ArchConfig, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, mesh))


def batch_pspec(mesh) -> P:
    return P(data_axes(mesh))


def batch_shardings(cfg: ArchConfig, specs, mesh):
    """Inputs: batch dim over (pod, data); feature dims replicated."""
    bd = data_axes(mesh)

    def one(s):
        spec = [None] * len(s.shape)
        if s.shape[0] % max(1, _prod(mesh.shape[a] for a in bd)) == 0:
            spec[0] = bd
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


def cache_shardings(cfg: ArchConfig, specs, mesh, *, shard_seq=False):
    """KV caches: batch dim (index 1 after the layer stack dim) over
    (pod,data); head dim over model where divisible.  ``shard_seq``:
    context-parallel decode — shard the cache sequence dim over model
    when heads aren't divisible (hillclimb option, EXPERIMENTS §Perf)."""
    bd = data_axes(mesh)
    dp = _prod(mesh.shape[a] for a in bd)
    parts = model_axis_size(mesh)

    def one(s):
        spec = [None] * len(s.shape)
        if len(s.shape) >= 2 and s.shape[1] % dp == 0:
            spec[1] = bd
        # (L, B, S, KV, hd): shard KV heads if divisible
        if len(s.shape) == 5:
            if s.shape[3] % parts == 0:
                spec[3] = "model"
            elif shard_seq and s.shape[2] % parts == 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def replicated(mesh):
    return NamedSharding(mesh, P())
