"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON records (idempotent: replaces between markers)."""
from __future__ import annotations

import glob
import json
import os
import sys

DRYRUN_DIR = "experiments/dryrun"
TARGET = "EXPERIMENTS.md"
MARK_A = "<!-- AUTOGEN:DRYRUN -->"
MARK_B = "<!-- AUTOGEN:END -->"


def load(dirname=DRYRUN_DIR):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile_s | args GiB/dev | "
           "temp GiB/dev | collectives (count) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAIL | | | {r.get('error','')} |")
            continue
        det = r.get("coll_detail", {})
        cd = "; ".join(f"{k}×{v[0]}" for k, v in sorted(det.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s','')} | {fmt_bytes(r.get('arg_bytes',0))} | "
            f"{fmt_bytes(r.get('temp_bytes',0))} | {cd} |")
    return "\n".join(out)


def roofline_table(rows, mesh="single"):
    out = ["| arch | shape | t_compute s | t_memory s | t_collective s | "
           "bottleneck | MODEL_FLOPS | useful ratio |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok") or r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3f} | "
            f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.3f} |")
    return "\n".join(out)


def render(rows):
    return f"""{MARK_A}
## §Dry-run — lower+compile proof, memory analysis, collective schedule

Every (architecture × applicable shape) cell compiled on BOTH production
meshes: single-pod (16×16 = 256 chips, axes data×model) and multi-pod
(2×16×16 = 512 chips, axes pod×data×model).  {sum(1 for r in rows if r.get('ok'))} compilations OK,
{sum(1 for r in rows if not r.get('ok'))} failed.  ``long_500k`` runs only for the sub-quadratic archs
(hymba, rwkv6) per the assignment; the 8 full-attention archs skip it
(DESIGN.md §4).  Args/temp are the CPU-backend ``memory_analysis()``
(args exact; temp an unfused upper bound — see §Roofline method note).

{dryrun_table(rows)}

## §Roofline — per-cell terms (single-pod), scan-trip-corrected

Terms per DESIGN.md §6: compute = HLO_FLOPs/dev ÷ 197 TF/s; memory =
fusion-aware HBM bytes ÷ 819 GB/s; collective = Σ collective result
bytes ÷ 50 GB/s.  ``useful ratio`` = MODEL_FLOPS / (HLO_FLOPs × chips)
(6·N_active·tokens for train, 2·N_active·tokens for serve).

{roofline_table(rows, "single")}

### Multi-pod (512-chip) roofline

{roofline_table(rows, "multi")}
{MARK_B}"""


def main():
    rows = load()
    block = render(rows)
    if os.path.exists(TARGET):
        text = open(TARGET).read()
        if MARK_A in text and MARK_B in text:
            pre = text.split(MARK_A)[0]
            post = text.split(MARK_B)[1]
            text = pre + block + post
        else:
            text = text + "\n" + block + "\n"
    else:
        text = block + "\n"
    with open(TARGET, "w") as f:
        f.write(text)
    print(f"wrote {TARGET} ({len(rows)} records)")


if __name__ == "__main__":
    main()
