"""Serving launcher: prefill + batched decode with a KV cache.

CPU-runnable with --reduced; the same decode_step lowers on the
production mesh (dry-run decode cells).

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import ShapeCell
from repro.models import lm


def generate(cfg, params, prompt, max_len: int, gen: int, *,
             temperature=0.0, seed=0):
    """Greedy/temperature decode of ``gen`` tokens after teacher-forcing
    the prompt through decode_step (exercises the cache path end to end)."""
    B, P = prompt.shape
    cell = ShapeCell("serve", max_len, B, "decode")
    cache = lm.init_cache(cfg, cell)
    step = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))
    key = jax.random.PRNGKey(seed)
    tok = prompt[:, :1]
    out = [tok]
    logits = None
    for pos in range(P + gen - 1):
        logits, cache = step(params, tok, cache, jnp.int32(pos))
        if pos + 1 < P:
            tok = prompt[:, pos + 1:pos + 2]          # teacher forcing
        else:
            if temperature > 0:
                key, k = jax.random.split(key)
                tok = jax.random.categorical(
                    k, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    seq = generate(cfg, params, prompt, args.prompt_len + args.gen,
                   args.gen, temperature=args.temperature)
    dt = time.time() - t0
    n_tok = args.batch * (args.prompt_len + args.gen)
    print(f"generated {seq.shape} in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. warmup)")
    print("sample:", np.asarray(seq[0, :24]).tolist())
    return seq


if __name__ == "__main__":
    main()
