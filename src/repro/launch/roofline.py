"""Roofline-term extraction from a compiled dry-run artifact.

  compute    = HLO_FLOPs(per-device program) / 197e12   (bf16 MXU peak)
  memory     = HLO_bytes(per-device)        / 819e9     (HBM)
  collective = Σ collective operand bytes    / 50e9      (per ICI link)

``cost_analysis()`` reports the per-device SPMD program (verified in the
prototype: total FLOPs / 512 matched).  Collective bytes are NOT in
cost_analysis — we parse the compiled HLO text and sum the *result shape*
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (result-size is the standard per-device traffic proxy;
reduce-scatter moves ~shards× its result, noted as underestimate).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * b


# Ops whose operands/results genuinely move through HBM on TPU.  Pure
# elementwise chains (convert/add/mul/select/...), broadcasts and
# reshapes fuse into neighbours on the TPU backend; the CPU-compiled HLO
# leaves them unfused, so cost_analysis()'s "bytes accessed" overstates
# HBM traffic ~10× (measured: 493 unfused f32 activation converts in one
# qwen2 layer).  This estimator prices the fusion-boundary ops only.
_HBM_OPS = {
    "dot", "fusion", "convolution", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "copy", "custom-call", "cholesky", "triangular-solve",
}

_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%[\w.\-]+ = ([a-z0-9]+)\[([0-9,]*)\][^ ]* ([\w\-]+)\(")
_OPERAND_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]\{?[0-9,]*\}?\s+%")


def hbm_bytes_fused(hlo_text: str) -> float:
    """Fusion-aware HBM byte estimate over the ENTRY computation.

    Valid for cost-mode compiles (scans unrolled → no nested while
    bodies); fusion-internal ops are priced through the fusion node's
    own operands/result."""
    total = 0
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            in_entry = False
            continue
        if not in_entry:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _root, dtype, dims, op = m.groups()
        if op == "parameter":
            total += _shape_bytes(dtype, dims)      # read once
            continue
        if op in _HBM_OPS:
            total += _shape_bytes(dtype, dims)      # result write
            for om in _OPERAND_RE.finditer(line):   # operand reads
                total += _shape_bytes(*om.groups())
    return float(total)


def collective_bytes(hlo_text: str) -> dict:
    """→ {op_kind: (count, bytes)} summed over the module."""
    out: dict[str, list] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        e = out.setdefault(kind, [0, 0])
        e[0] += 1
        e[1] += _shape_bytes(dtype, dims)
    for m in _TUPLE_COLL_RE.finditer(hlo_text):
        parts, kind = m.groups()
        total = 0
        for t in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", parts):
            total += _shape_bytes(*t.groups())
        e = out.setdefault(kind, [0, 0])
        e[0] += 1
        e[1] += total
    return {k: tuple(v) for k, v in out.items()}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes
    coll_detail: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float           # 6·N·D (train) / 2·N_active·tokens (serve)
    useful_ratio: float          # model_flops / (flops · n_devices)
    bytes_per_device: int        # peak memory from memory_analysis
    n_devices: int

    def to_json(self):
        return json.dumps(asdict(self), indent=1)


def analyze(arch: str, shape: str, mesh_name: str, compiled, *,
            model_flops: float, n_devices: int) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    det = collective_bytes(compiled.as_text())
    coll = float(sum(b for _, b in det.values()))
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    ma = compiled.memory_analysis()
    peak = int(ma.temp_size_in_bytes + ma.argument_size_in_bytes
               + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=flops, hbm_bytes=hbm, coll_bytes=coll, coll_detail=det,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=max(terms, key=terms.get),
        model_flops=model_flops,
        useful_ratio=model_flops / max(1.0, flops * n_devices),
        bytes_per_device=peak, n_devices=n_devices)


def param_count(cfg) -> float:
    """Total / active parameter counts from the model defs."""
    from repro.models import lm
    total = 0
    active = 0
    for path, (shape, _role) in jax.tree_util.tree_flatten_with_path(
            lm.model_defs(cfg), is_leaf=lm._is_shape_leaf)[0]:
        n = 1
        for d in shape:
            n *= d
        total += n
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if name.startswith("layers/ew") or name.startswith("glayers/ew"):
            n = n * cfg.top_k // max(1, cfg.n_experts)
        active += n
    return float(total), float(active)


def model_flops_for(cfg, cell) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for serve."""
    total, active = param_count(cfg)
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * active * tokens
    if cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * active * tokens
    return 2.0 * active * cell.global_batch     # decode: one token/seq


import jax  # noqa: E402  (used by param_count's tree utils)
