"""Jitted train / prefill / decode step builders with explicit shardings.

``make_train_step`` = loss + grad + AdamW update (bf16 params, f32 opt
state), batch sharded over (pod, data), params/opt over the TP rules.
``make_decode_step`` = one serve token, cache donated (in-place update).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import lm, sharding_ctx
from repro.optim import AdamWConfig, adamw_init, adamw_update
from . import sharding as sh
from .mesh import data_axes


def opt_state_specs(cfg: ArchConfig):
    p = lm.param_specs(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {"m": jax.tree.map(f32, p), "v": jax.tree.map(f32, p),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _zero1_spec(pspec: P, shape, mesh):
    """ZeRO-1: additionally shard optimizer state over the data axis on
    the first still-unsharded, divisible dim."""
    dsize = mesh.shape.get("data", 1)
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (s, cur) in enumerate(zip(shape, spec)):
        if cur is None and s % dsize == 0 and s >= dsize:
            spec[i] = "data"
            return P(*spec)
    return pspec


def opt_state_shardings(cfg: ArchConfig, mesh, zero1: bool = False):
    ps = sh.param_shardings(cfg, mesh)
    if zero1:
        pspecs = sh.param_pspecs(cfg, mesh)
        specs = lm.map_defs(lambda d: d, lm.model_defs(cfg))
        z = jax.tree.map(
            lambda d, p: NamedSharding(mesh, _zero1_spec(p, d[0], mesh)),
            specs, pspecs, is_leaf=lambda x: lm._is_shape_leaf(x))
        ps = z
    return {"m": ps, "v": ps, "step": sh.replicated(mesh)}


def make_train_fn(cfg: ArchConfig, opt_cfg: AdamWConfig, mesh, *,
                  chunk=1024):
    bd = data_axes(mesh)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            b = {k: (jax.lax.with_sharding_constraint(
                    v, NamedSharding(mesh, P(bd, *[None] * (v.ndim - 1))))
                    if v.ndim >= 1 else v)
                 for k, v in batch.items()}
            return lm.train_loss(p, cfg, b, chunk=chunk)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    return train_step


def jit_train_step(cfg: ArchConfig, cell: ShapeCell, mesh, opt_cfg=None, *,
                   chunk=1024, zero1: bool = False):
    sharding_ctx.set_mesh(mesh)
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-4, grad_clip=1.0)
    pshard = sh.param_shardings(cfg, mesh)
    oshard = opt_state_shardings(cfg, mesh, zero1=zero1)
    bshard = sh.batch_shardings(cfg, lm.input_specs(cfg, cell), mesh)
    fn = make_train_fn(cfg, opt_cfg, mesh, chunk=chunk)
    return jax.jit(
        fn,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, sh.replicated(mesh)),
        donate_argnums=(0, 1),
    )


def make_prefill_fn(cfg: ArchConfig, *, chunk=1024):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, chunk=chunk)
    return prefill_step


def jit_prefill_step(cfg: ArchConfig, cell: ShapeCell, mesh, *, chunk=1024):
    sharding_ctx.set_mesh(mesh)
    pshard = sh.param_shardings(cfg, mesh)
    bshard = sh.batch_shardings(cfg, lm.input_specs(cfg, cell), mesh)
    return jax.jit(make_prefill_fn(cfg, chunk=chunk),
                   in_shardings=(pshard, bshard))


def make_decode_fn(cfg: ArchConfig):
    def decode(params, token, cache, pos):
        return lm.decode_step(params, cfg, token, cache, pos)
    return decode


def jit_decode_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                    shard_cache_seq: bool = False):
    sharding_ctx.set_mesh(mesh)
    pshard = sh.param_shardings(cfg, mesh)
    cshard = sh.cache_shardings(cfg, lm.cache_specs(cfg, cell), mesh,
                                shard_seq=shard_cache_seq)
    tshard = sh.batch_shardings(
        cfg, jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32), mesh)
    return jax.jit(
        make_decode_fn(cfg),
        in_shardings=(pshard, tshard, cshard, sh.replicated(mesh)),
        out_shardings=(None, cshard),
        donate_argnums=(2,),
    )
