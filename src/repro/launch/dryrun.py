import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first init; only the dry-run sees 512 placeholder devices.

For each cell:  jit(step).lower(**ShapeDtypeStructs).compile() under the
production mesh; print memory_analysis (fits?) and cost_analysis
(FLOPs/bytes for §Roofline); write JSON to experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, applicable_shapes
from repro.models import lm
from . import roofline, sharding as sh, steps
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def compile_cell(cfg, cell, mesh, *, chunk=1024, opts=None):
    from repro.models.common import set_perf_options, reset_perf_options
    opts = opts or {}
    reset_perf_options()
    from repro.models.common import PERF_DEFAULTS
    set_perf_options(**{k: v for k, v in opts.items()
                        if k in PERF_DEFAULTS})
    with mesh:
        if cell.kind == "train":
            fn = steps.jit_train_step(cfg, cell, mesh, chunk=chunk,
                                      zero1=opts.get("zero1", False))
            args = (lm.param_specs(cfg), steps.opt_state_specs(cfg),
                    lm.input_specs(cfg, cell))
        elif cell.kind == "prefill":
            fn = steps.jit_prefill_step(cfg, cell, mesh, chunk=chunk)
            args = (lm.param_specs(cfg), lm.input_specs(cfg, cell))
        else:   # decode
            fn = steps.jit_decode_step(
                cfg, cell, mesh,
                shard_cache_seq=opts.get("shard_cache_seq", False))
            args = (lm.param_specs(cfg),
                    lm.input_specs(cfg, cell)["token"],
                    lm.cache_specs(cfg, cell),
                    jax.ShapeDtypeStruct((), jax.numpy.int32))
        return fn.lower(*args).compile()


def _cost(compiled):
    """Flat cost vector: flops, bytes, per-kind collective count/bytes.

    bytes      — fusion-aware HBM estimate (roofline.hbm_bytes_fused);
    bytes_raw  — cost_analysis()'s unfused upper bound, kept for record.
    """
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    det = roofline.collective_bytes(txt)
    out = {"flops": float(ca.get("flops", 0.0)),
           "bytes": roofline.hbm_bytes_fused(txt),
           "bytes_raw": float(ca.get("bytes accessed", 0.0))}
    for k, (n, b) in det.items():
        out[f"coll::{k}::n"] = float(n)
        out[f"coll::{k}::b"] = float(b)
    return out


def _vec(op, *costs):
    keys = set().union(*[c.keys() for c in costs])
    return {k: max(0.0, op(*[c.get(k, 0.0) for c in costs])) for k in keys}


def _unflatten_cost(flat):
    coll = {}
    for k, v in flat.items():
        if k.startswith("coll::"):
            _, kind, field = k.split("::")
            e = coll.setdefault(kind, [0, 0])
            e[0 if field == "n" else 1] = int(v)
    return {"flops": flat.get("flops", 0.0), "bytes": flat.get("bytes", 0.0),
            "bytes_raw": flat.get("bytes_raw", 0.0),
            "coll": {k: tuple(v) for k, v in coll.items()}}


def _layer_variants(cfg):
    """(base_cfg, [(true_count, variant_cfg), ...]) for scan-trip
    extrapolation — cost_analysis counts a while body ONCE regardless of
    trip count, so cost variants compile with layer scans UNROLLED
    (models.common cost mode) at L ∈ {1,2} and extrapolate linearly."""
    if cfg.family == "hybrid":
        base = cfg.replace(n_layers=2, n_global_layers=1)
        return base, [
            (cfg.n_layers - cfg.n_global_layers,
             cfg.replace(n_layers=3, n_global_layers=1)),
            (cfg.n_global_layers,
             cfg.replace(n_layers=3, n_global_layers=2)),
        ]
    if cfg.family == "encdec":
        base = cfg.replace(n_layers=1, n_enc_layers=1)
        return base, [
            (cfg.n_layers, cfg.replace(n_layers=2, n_enc_layers=1)),
            (cfg.n_enc_layers, cfg.replace(n_layers=1, n_enc_layers=2)),
        ]
    base = cfg.replace(n_layers=1)
    return base, [(cfg.n_layers, cfg.replace(n_layers=2))]


def scan_aware_cost(cfg, cell, mesh, *, opts=None):
    """Roofline cost with scan-trip correction.  Cost compiles run in
    cost mode (unrolled layer/time scans) and with chunk=seq (no q-chunk
    or loss-chunk while loops).  RWKV's time recurrence additionally
    needs (L, S) bilinear extrapolation — its per-token cost lives in a
    4096..524288-trip time scan that can only be unrolled at tiny S."""
    from repro.models.common import set_cost_mode
    set_cost_mode(True)
    try:
        if cfg.family == "ssm" and cell.kind != "decode":
            return _rwkv_bilinear_cost(cfg, cell, mesh, opts=opts)
        chunk = cell.seq_len
        base_cfg, variants = _layer_variants(cfg)
        base = _cost(compile_cell(base_cfg, cell, mesh, chunk=chunk,
                                  opts=opts))
        flat = dict(base)
        for count, vc in variants:
            var = _cost(compile_cell(vc, cell, mesh, chunk=chunk, opts=opts))
            delta = _vec(lambda v, b: v - b, var, base)
            flat = _vec(lambda t, d: t + (count - 1) * d, flat, delta)
        return _unflatten_cost(flat)
    finally:
        set_cost_mode(False)


def _rwkv_bilinear_cost(cfg, cell, mesh, *, opts=None, s0=16, s1=32):
    """cost(L,S) = α + βL + γS + δLS fitted from 4 unrolled compiles."""
    from repro.configs.base import ShapeCell

    def cc(L, S):
        c = ShapeCell(cell.name, S, cell.global_batch, cell.kind)
        return _cost(compile_cell(cfg.replace(n_layers=L), c, mesh,
                                  chunk=S, opts=opts))

    c11, c21 = cc(1, s0), cc(2, s0)
    c12, c22 = cc(1, s1), cc(2, s1)
    L, S = cfg.n_layers, cell.seq_len
    ds = s1 - s0

    def fit(k):
        a11, a21 = c11.get(k, 0.0), c21.get(k, 0.0)
        a12, a22 = c12.get(k, 0.0), c22.get(k, 0.0)
        delta = ((a22 - a12) - (a21 - a11)) / ds
        beta = (a21 - a11) - delta * s0
        gamma = (a12 - a11) / ds - delta
        alpha = a11 - beta - gamma * s0 - delta * s0
        return max(0.0, alpha + beta * L + gamma * S + delta * L * S)

    keys = set(c11) | set(c21) | set(c12) | set(c22)
    return _unflatten_cost({k: fit(k) for k in keys})


def run_cell(arch: str, shape: str, mesh_name: str, verbose=True,
             opts=None, full_compile=True):
    t0 = time.time()
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = 512 if mesh_name == "multi" else 256

    # 1) full-config compile: proof of lowering + memory analysis
    if full_compile:
        compiled = compile_cell(cfg, cell, mesh, opts=opts)
        ma = compiled.memory_analysis()
        peak = int(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                   + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        mem = {"per_device_bytes": peak,
               "arg_bytes": int(ma.argument_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes)}
        del compiled
    else:
        mem = {}

    # 2) scan-trip-corrected roofline terms
    cost = scan_aware_cost(cfg, cell, mesh, opts=opts)
    coll_bytes = float(sum(b for _, b in cost["coll"].values()))
    t_c = cost["flops"] / roofline.PEAK_FLOPS
    t_m = cost["bytes"] / roofline.HBM_BW
    t_x = coll_bytes / roofline.LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    mf = roofline.model_flops_for(cfg, cell)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "ok": True,
        "compile_s": round(time.time() - t0, 1), **mem,
        "flops": cost["flops"], "hbm_bytes": cost["bytes"],
        "hbm_bytes_raw": cost.get("bytes_raw", 0.0),
        "coll_bytes": coll_bytes, "coll_detail": cost["coll"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "bottleneck": max(terms, key=terms.get),
        "model_flops": mf,
        "useful_ratio": mf / max(1.0, cost["flops"] * n_dev),
        "opts": opts or {},
    }
    if verbose:
        mem_s = (f"mem/dev={rec['per_device_bytes']/2**30:.2f}GiB "
                 if mem else "")
        print(f"[{arch} × {shape} × {mesh_name}] OK "
              f"compile={rec['compile_s']}s {mem_s}"
              f"t=(c {t_c*1e3:.2f} | m {t_m*1e3:.2f} | "
              f"x {t_x*1e3:.2f})ms → {rec['bottleneck']} "
              f"useful={rec['useful_ratio']:.3f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--opt", action="append", default=[],
                    help="perf option key=value (zero1=true, "
                         "moe_dispatch=batched, remat_policy=dots, "
                         "ssm_scan_dtype=bfloat16, shard_cache_seq=true)")
    args = ap.parse_args(argv)

    opts = {}
    for o in args.opt:
        k, v = o.split("=", 1)
        opts[k] = {"true": True, "false": False}.get(v, v)

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape else applicable_shapes(cfg))
        for shape in shapes:
            for mesh_name in meshes:
                key = f"{arch}_{shape}_{mesh_name}"
                try:
                    rec = run_cell(arch, shape, mesh_name, opts=opts)
                except Exception as e:   # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "ok": False, "error": f"{type(e).__name__}: {e}"}
                    failures.append(key)
                with open(os.path.join(args.out, key + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete: all cells compiled")


if __name__ == "__main__":
    main()
