"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
outer data parallelism whose gradient all-reduce crosses DCI once/step.

Defined as functions (never module-level constants) so importing this
module cannot touch jax device state — smoke tests must keep seeing one
CPU device; only ``dryrun.py`` forces 512 host devices.
"""
from __future__ import annotations

import jax
import numpy as np


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the installed jax has it (≥ 0.5 introduced
    ``jax.sharding.AxisType``); on older releases every axis is Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        **_axis_type_kwargs(2))


def make_partition_mesh(n_parts: int, devices=None):
    """1D ``("parts",)`` mesh over ``n_parts`` devices — the axis the
    distributed graph subsystem (``repro.dist``) shards partitions along.
    Kept separate from the data/model training meshes: graph partitions
    are a *spatial* split of one sparse operator, not batch parallelism
    (a ``DistGraph`` can later be nested under an outer data axis by
    passing a submesh here via ``devices``).  Axes are explicitly Auto
    where the installed jax distinguishes axis types, matching the
    training meshes above."""
    devs = list(jax.devices()) if devices is None else list(devices)
    if n_parts > len(devs):
        raise ValueError(
            f"{n_parts} partitions need {n_parts} devices, have {len(devs)} "
            "(CPU: set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    try:
        return jax.sharding.Mesh(np.asarray(devs[:n_parts]), ("parts",),
                                 **_axis_type_kwargs(1))
    except TypeError:          # older jax: Mesh has no axis_types kwarg
        return jax.sharding.Mesh(np.asarray(devs[:n_parts]), ("parts",))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
