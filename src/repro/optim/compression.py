"""Gradient compression for cross-pod data parallelism: top-k
sparsification with error feedback (memory), the standard trick for
bandwidth-bound DP all-reduce at 1000+-node scale.

Compression happens *before* the cross-pod reduction: each replica keeps
the residual locally so the update stays unbiased in the long run.  Used
as an opt-in wrapper around the optimizer (``launch/train.py --compress``);
tests check convergence-neutrality on small runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_compress_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(x, frac: float):
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_compress_apply(grads, error, frac: float = 0.05):
    """Returns (compressed grads to all-reduce, new error memory)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        mask = _topk_mask(g32, frac)
        sent = g32 * mask
        return sent.astype(g.dtype), g32 - sent

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
