from .adamw import adamw_init, adamw_update, AdamWConfig
from .compression import topk_compress_init, topk_compress_apply
