"""AdamW as pure pytree functions (f32 state over bf16/f32 params)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0     # global-norm clip; 0 disables


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    if cfg.grad_clip > 0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - cfg.lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                              + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
