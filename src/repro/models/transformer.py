"""Decoder-only transformer families: dense GQA (qwen2/qwen1.5/chatglm3/
mistral-llava), gemma2 (alternating local/global + softcaps + post-norms),
and granite-style MoE.  Stacked-parameter layout, ``lax.scan`` over layers,
query-chunked attention and sequence-chunked cross-entropy so 32k-sequence
cells fit per-device memory at lowering time.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .common import (apply_norm, apply_rope, gated_mlp, gqa_attention,
                     rope_tables, scan_layers, softcap, NEG_INF)
from .sharding_ctx import constrain_attn_q, constrain_heads, constrain_hidden

Pytree = Any


# ----------------------------------------------------------- param defs
def dense_layer_defs(cfg: ArchConfig) -> dict:
    """(shape, role) per stacked layer tensor. Roles map to PartitionSpecs
    in launch/sharding.py."""
    L, D = cfg.n_layers, cfg.d_model
    qd, kvd, ff = cfg.q_dim, cfg.kv_dim, cfg.d_ff
    defs = {
        "ln1": {"w": ((L, D), "rep")},
        "ln2": {"w": ((L, D), "rep")},
        "wq": ((L, D, qd), "col"),
        "wk": ((L, D, kvd), "col"),
        "wv": ((L, D, kvd), "col"),
        "wo": ((L, qd, D), "row"),
    }
    if cfg.qkv_bias:
        defs["bq"] = ((L, qd), "col_b")
        defs["bk"] = ((L, kvd), "col_b")
        defs["bv"] = ((L, kvd), "col_b")
    if cfg.n_experts:
        eff = cfg.expert_d_ff
        defs["router"] = ((L, D, cfg.n_experts), "rep")
        defs["ewg"] = ((L, cfg.n_experts, D, eff), "expert_in")
        defs["ewu"] = ((L, cfg.n_experts, D, eff), "expert_in")
        defs["ewd"] = ((L, cfg.n_experts, eff, D), "expert_down")
    else:
        defs["wg"] = ((L, D, ff), "col")
        defs["wu"] = ((L, D, ff), "col")
        defs["wd"] = ((L, ff, D), "row")
    if cfg.post_block_norm:
        defs["ln1_post"] = {"w": ((L, D), "rep")}
        defs["ln2_post"] = {"w": ((L, D), "rep")}
    if cfg.norm == "layernorm":
        for k in ("ln1", "ln2", "ln1_post", "ln2_post"):
            if k in defs:
                defs[k]["b"] = (defs[k]["w"][0], "rep")
    return defs


def dense_model_defs(cfg: ArchConfig) -> dict:
    defs = {
        "embed": ((cfg.vocab_padded, cfg.d_model), "embed"),
        "final_norm": {"w": ((cfg.d_model,), "rep")},
        "layers": dense_layer_defs(cfg),
    }
    if cfg.norm == "layernorm":
        defs["final_norm"]["b"] = ((cfg.d_model,), "rep")
    if not cfg.tie_embeddings:
        defs["lm_head"] = ((cfg.d_model, cfg.vocab_padded), "col")
    return defs


# ------------------------------------------------------- chunked attention
def chunked_attention(q, k, v, *, causal=True, window=0, attn_softcap=0.0,
                      local_flag=None, q_offset=0, chunk=1024):
    """Query-chunked GQA attention: full K/V per chunk, bounded score
    memory.  ``local_flag`` (traced bool) toggles the sliding window at
    runtime (gemma2 alternation inside one scanned layer body)."""
    B, Sq, H, hd = q.shape
    if Sq <= chunk:
        return _attn_block(q, k, v, causal=causal, window=window,
                           attn_softcap=attn_softcap, local_flag=local_flag,
                           q_offset=q_offset)
    assert Sq % chunk == 0
    nq = Sq // chunk
    qs = q.reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, qc_i):
        qc, i = qc_i
        out = _attn_block(qc, k, v, causal=causal, window=window,
                          attn_softcap=attn_softcap, local_flag=local_flag,
                          q_offset=q_offset + i * chunk)
        return None, out

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _attn_block(q, k, v, *, causal, window, attn_softcap, local_flag,
                q_offset):
    """GQA via repeat-KV: K/V broadcast to H heads so scores shard
    cleanly over the (divisible) q-head dim — the reshape-to-groups form
    broke GSPMD head sharding and replicated the score tensor."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if KV != H:
        k = constrain_heads(jnp.repeat(k, H // KV, axis=2))
        v = constrain_heads(jnp.repeat(v, H // KV, axis=2))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_softcap > 0:
        scores = softcap(scores, attn_softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        wmask = kpos[None, :] > qpos[:, None] - window
        if local_flag is not None:
            wmask = wmask | ~local_flag
        mask &= wmask
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ------------------------------------------------------------------- MoE
def moe_ffn(x, router_w, ewg, ewu, ewd, *, top_k: int, act: str,
            capacity_factor: float = 1.25, dispatch: str | None = None):
    """Scatter-based top-k dispatch with fixed expert capacity (static
    shapes → no data-dependent recompiles; drops overflow tokens like
    production MoE runtimes — the straggler-mitigation choice).

    dispatch="global" (baseline): one queue over ALL tokens — a direct
    GPU-style port whose rank cumsum runs over the global token axis and
    therefore cannot shard (EXPERIMENTS §Perf baseline).
    dispatch="batched" (optimized): per-sequence queues — the PCSR
    S=True idea (fixed-capacity balanced chunks) applied to routing: the
    cumsum/scatter/gather all carry the batch dim, so the whole dispatch
    pipeline shards over (pod, data) with zero extra collectives."""
    from .common import perf_option
    dispatch = dispatch or perf_option("moe_dispatch")
    if dispatch == "batched":
        return _moe_ffn_batched(x, router_w, ewg, ewu, ewd, top_k=top_k,
                                act=act, capacity_factor=capacity_factor)
    if dispatch == "shard_map":
        return _moe_ffn_shard_map(x, router_w, ewg, ewu, ewd, top_k=top_k,
                                  act=act, capacity_factor=capacity_factor)
    B, S, D = x.shape
    E = router_w.shape[-1]
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ router_w.astype(x.dtype)).astype(jnp.float32)
    gates, eidx = jax.lax.top_k(logits, top_k)              # (T, k)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)
    cap = max(8, int(capacity_factor * top_k * T / E))
    # position of each (token, slot) within its expert queue
    onehot_flat = eidx.reshape(-1)                          # (T*k,)
    pos = _positions_in_expert(onehot_flat, E)              # (T*k,)
    keep = (pos < cap).astype(x.dtype)
    # dispatch: (E, cap, D) scatter-add
    buf = jnp.zeros((E, cap, D), x.dtype)
    xrep = jnp.repeat(xt, top_k, axis=0)                    # (T*k, D)
    buf = buf.at[onehot_flat, jnp.minimum(pos, cap - 1)].add(
        xrep * keep[:, None])
    # expert FFN, batched over E
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = jnp.einsum("ecd,edf->ecf", buf, ewg.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, ewu.astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", a(h) * u, ewd.astype(x.dtype))
    # combine: gather back and weight by gate
    out = y[onehot_flat, jnp.minimum(pos, cap - 1)] * (gates.reshape(-1)
                                                       * keep)[:, None]
    return out.reshape(T, top_k, D).sum(1).reshape(B, S, D)


def _moe_ffn_batched(x, router_w, ewg, ewu, ewd, *, top_k: int, act: str,
                     capacity_factor: float, constrain: bool = True):
    """Shard-local dispatch: every tensor keeps the batch dim, so GSPMD
    keeps the one-hot rank cumsum, scatter and gather on-device."""
    from .sharding_ctx import constrain_moe_buf
    B, S, D = x.shape
    E = router_w.shape[-1]
    k = top_k
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)  # (B,S,E)
    gates, eidx = jax.lax.top_k(logits, k)                       # (B,S,k)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)
    cap = max(8, -(-int(capacity_factor * k * S / E) // 16) * 16)
    eflat = eidx.reshape(B, S * k)
    onehot = jax.nn.one_hot(eflat, E, dtype=jnp.int32)           # (B,S·k,E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot                  # per-seq
    pos = jnp.take_along_axis(ranks, eflat[..., None],
                              axis=2)[..., 0]                    # (B,S·k)
    keep = (pos < cap).astype(x.dtype)
    pos_c = jnp.minimum(pos, cap - 1)
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], eflat.shape)
    # gather-based dispatch (§Perf iteration 4): scatter only the int32
    # token-id map, then GATHER activations into the expert buffer —
    # avoids materializing x repeated k× and the read-modify-write
    # scatter-add of the (B,E,cap,D) buffer.
    tok_src = jnp.broadcast_to(jnp.arange(S * k, dtype=jnp.int32) // k,
                               eflat.shape)
    tokmap = jnp.zeros((B, E, cap), jnp.int32)
    tokmap = tokmap.at[b_ix, eflat, pos_c].set(tok_src)
    valid = jnp.zeros((B, E, cap), x.dtype)
    valid = valid.at[b_ix, eflat, pos_c].max(keep)
    buf = jnp.take_along_axis(
        x[:, None], tokmap.reshape(B, 1, E * cap)[..., None], axis=2
    ).reshape(B, E, cap, D) * valid[..., None]
    if constrain:
        buf = constrain_moe_buf(buf)
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    # fused gate|up projection: one read of the buffer instead of two
    hu = jnp.einsum("becd,edf->becf", buf,
                    jnp.concatenate([ewg, ewu], -1).astype(x.dtype))
    ff = ewg.shape[-1]
    y = jnp.einsum("becf,efd->becd", a(hu[..., :ff]) * hu[..., ff:],
                   ewd.astype(x.dtype))
    out = y[b_ix, eflat, pos_c] * (gates.reshape(B, S * k)
                                   * keep)[..., None]
    return out.reshape(B, S, k, D).sum(2)


def _moe_ffn_shard_map(x, router_w, ewg, ewu, ewd, *, top_k: int, act: str,
                       capacity_factor: float):
    """Explicit-collective MoE (the hillclimbed variant, §Perf): batch
    shards over (pod, data), expert ff over model.  Dispatch, expert
    matmuls and combine are all LOCAL; the combine is linear in the
    down-projection partial sums, so the ONLY collective is one psum of
    the (B,S,D) layer output — versus per-(E,cap) all-gathers/reduces
    when GSPMD is left to place them."""
    from jax.sharding import PartitionSpec as P
    from .sharding_ctx import get_mesh
    mesh = get_mesh()
    parts = mesh.shape.get("model", 1) if mesh is not None else 1
    ff = ewg.shape[-1]
    if mesh is None or parts <= 1 or ff % parts:
        return _moe_ffn_batched(x, router_w, ewg, ewu, ewd, top_k=top_k,
                                act=act, capacity_factor=capacity_factor)
    bd = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def local_fn(xl, rw, g, u, d):
        y_partial = _moe_ffn_batched(xl, rw, g, u, d, top_k=top_k, act=act,
                                     capacity_factor=capacity_factor,
                                     constrain=False)
        return jax.lax.psum(y_partial, "model")

    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bd, None, None), P(),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=P(bd, None, None),
        check_vma=False,
    )(x, router_w, ewg, ewu, ewd)


def _positions_in_expert(eidx_flat, E: int):
    """Rank of each entry within its expert (cumulative count)."""
    Tk = eidx_flat.shape[0]
    onehot = jax.nn.one_hot(eidx_flat, E, dtype=jnp.int32)   # (T·k, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(ranks, eidx_flat[:, None], axis=1)[:, 0]


# ------------------------------------------------------------ layer body
def dense_layer(x, lp, cfg: ArchConfig, *, cos, sin, rot, layer_idx,
                cache=None, pos=None, chunk=1024):
    """One transformer block. cache=(k,v) (B,Smax,KV,hd) → decode mode,
    returns (x, new_cache)."""
    B, Sq, D = x.shape
    h = apply_norm(x, lp["ln1"], cfg.norm, cfg.norm_plus_one)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = constrain_attn_q(q.reshape(B, Sq, cfg.n_heads, cfg.head_dim))
    k = constrain_heads(k.reshape(B, Sq, cfg.n_kv, cfg.head_dim))
    v = constrain_heads(v.reshape(B, Sq, cfg.n_kv, cfg.head_dim))
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)

    local_flag = None
    window = cfg.sliding_window
    if cfg.alternate_local_global and window > 0:
        local_flag = (layer_idx % 2 == 0)         # even layers local
    new_cache = None
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
        new_cache = (ck, cv)
        attn = _attn_block(q, ck, cv, causal=True, window=window,
                           attn_softcap=cfg.attn_softcap,
                           local_flag=local_flag, q_offset=pos)
    else:
        attn = chunked_attention(q, k, v, causal=True, window=window,
                                 attn_softcap=cfg.attn_softcap,
                                 local_flag=local_flag, chunk=chunk)
    attn = constrain_heads(attn).reshape(B, Sq, cfg.q_dim) @ lp["wo"]
    if cfg.post_block_norm:
        attn = apply_norm(attn, lp["ln1_post"], cfg.norm, cfg.norm_plus_one)
    x = constrain_hidden(x + attn)

    h = apply_norm(x, lp["ln2"], cfg.norm, cfg.norm_plus_one)
    if cfg.n_experts:
        f = moe_ffn(h, lp["router"], lp["ewg"], lp["ewu"], lp["ewd"],
                    top_k=cfg.top_k, act=cfg.act)
    else:
        f = gated_mlp(h, lp["wg"], lp["wu"], lp["wd"], act=cfg.act)
    if cfg.post_block_norm:
        f = apply_norm(f, lp["ln2_post"], cfg.norm, cfg.norm_plus_one)
    return constrain_hidden(x + f), new_cache


# --------------------------------------------------------------- forward
def dense_forward(params, cfg: ArchConfig, embeds, *, remat=True,
                  chunk=1024):
    """embeds (B,S,D) → final hidden states (B,S,D); scan over layers."""
    B, S, D = embeds.shape
    positions = jnp.arange(S)[None, :]
    cos, sin, rot = rope_tables(positions, cfg.head_dim, cfg.rope_fraction,
                                cfg.rope_base)

    def body(x, scanned):
        lp, idx = scanned
        fn = functools.partial(dense_layer, cfg=cfg, cos=cos, sin=sin,
                               rot=rot, chunk=chunk)
        if remat:
            from .common import perf_option
            policy = {
                "dots": jax.checkpoint_policies.dots_saveable,
                "dots_nb":
                    jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            }.get(perf_option("remat_policy"))
            fn = jax.checkpoint(lambda xx, ll, ii: dense_layer(
                xx, ll, cfg, cos=cos, sin=sin, rot=rot, layer_idx=ii,
                chunk=chunk)[0], policy=policy)
            return fn(x, lp, idx), None
        return fn(x, lp, layer_idx=idx)[0], None

    x, _ = scan_layers(body, embeds,
                        (params["layers"], jnp.arange(cfg.n_layers)))
    return apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_plus_one)


def dense_decode_step(params, cfg: ArchConfig, token_embed, cache, pos):
    """token_embed (B,1,D); cache {"k","v"}: (L,B,Smax,KV,hd).
    Returns (hidden (B,1,D), new cache)."""
    cos, sin, rot = rope_tables(pos[None, None], cfg.head_dim,
                                cfg.rope_fraction, cfg.rope_base)

    def body(x, scanned):
        lp, ck, cv, idx = scanned
        y, (nk, nv) = dense_layer(x, lp, cfg, cos=cos, sin=sin, rot=rot,
                                  layer_idx=idx, cache=(ck, cv), pos=pos)
        return y, (nk, nv)

    x, (nk, nv) = scan_layers(
        body, token_embed,
        (params["layers"], cache["k"], cache["v"], jnp.arange(cfg.n_layers)))
    x = apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_plus_one)
    return x, {"k": nk, "v": nv}


# ------------------------------------------------------------------ loss
def chunked_xent(x, embed, labels, *, logit_softcap=0.0, chunk=512,
                 lm_head=None, valid_vocab=None):
    """Sequence-chunked CE against (tied or untied) unembedding — the full
    (B,S,V) logits tensor is never materialized, and the label term is a
    one-hot contraction (a reduction over the vocab-parallel dim → cheap
    partial-sum all-reduce) rather than a gather (which would all-gather
    the sharded logits)."""
    B, S, D = x.shape
    W = embed.T if lm_head is None else lm_head        # (D, V)
    V = W.shape[-1]
    nc = max(1, S // chunk)
    while S % nc:                     # largest divisor ≤ target count
        nc -= 1
    chunk = S // nc
    xs = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, xl):
        xc, lc = xl
        logits = (xc @ W.astype(xc.dtype)).astype(jnp.float32)
        if logit_softcap > 0:
            logits = softcap(logits, logit_softcap)
        if valid_vocab is not None and valid_vocab < V:
            logits = jnp.where(jnp.arange(V) < valid_vocab, logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, V, dtype=logits.dtype)
        ll = (logits * onehot).sum(-1)
        return carry + (lse - ll).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (B * S)


def logits_for(x, params, cfg: ArchConfig):
    W = params.get("lm_head")
    W = params["embed"].T if W is None else W
    logits = (x @ W.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = softcap(logits, cfg.logit_softcap)
    if cfg.vocab_padded > cfg.vocab:       # mask Megatron vocab padding
        logits = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab,
                           logits, NEG_INF)
    return logits
