"""Unified LM wrapper: one entry point per assigned architecture family.

Provides, for every ``ArchConfig``:
  * ``model_defs(cfg)``        — pytree of (shape, role) leaves;
  * ``init_params(key, cfg)``  — materialized params (smoke tests);
  * ``param_specs(cfg)``       — ShapeDtypeStructs (dry-run, no alloc);
  * ``train_loss(params, cfg, batch)``;
  * ``prefill(params, cfg, batch)``     → (logits_last, cache);
  * ``decode_step(params, cfg, token, cache, pos)`` → (logits, cache);
  * ``cache_specs(cfg, cell)`` / ``input_specs(cfg, cell)``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell
from .common import apply_norm, scan_layers, softmax_xent
from .hybrid import hybrid_decode_step, hybrid_forward, hybrid_model_defs
from .ssm import rwkv_defs, rwkv_layer, RWKV_HEAD_DIM
from .transformer import (chunked_xent, dense_decode_step, dense_forward,
                          dense_model_defs, logits_for)
from .whisper import (whisper_decode_step, whisper_decode_train,
                      whisper_encode, whisper_model_defs)

DTYPE = jnp.bfloat16


# ------------------------------------------------------------- param defs
def model_defs(cfg: ArchConfig) -> dict:
    if cfg.family in ("dense", "moe", "vlm"):
        return dense_model_defs(cfg)
    if cfg.family == "hybrid":
        return hybrid_model_defs(cfg)
    if cfg.family == "ssm":
        return {
            "embed": ((cfg.vocab_padded, cfg.d_model), "embed"),
            "ln0": {"w": ((cfg.d_model,), "rep"),
                    "b": ((cfg.d_model,), "rep")},
            "final_norm": {"w": ((cfg.d_model,), "rep"),
                           "b": ((cfg.d_model,), "rep")},
            "layers": rwkv_defs(cfg),
        }
    if cfg.family == "encdec":
        return whisper_model_defs(cfg)
    raise ValueError(cfg.family)


def _is_shape_leaf(x):
    return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
            and isinstance(x[1], str))


def map_defs(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=_is_shape_leaf)


def param_specs(cfg: ArchConfig, dtype=DTYPE):
    return map_defs(lambda d: jax.ShapeDtypeStruct(d[0], dtype),
                    model_defs(cfg))


def init_params(key, cfg: ArchConfig, dtype=DTYPE):
    """Materialize params (reduced configs only — full configs are dry-run
    exercised via ShapeDtypeStructs)."""
    defs = model_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_shape_leaf)
    paths = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=_is_shape_leaf)[0]
    keys = jax.random.split(key, len(leaves))
    out = []
    for (path, (shape, _)), k in zip(paths, keys):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append(_init_one(k, name, shape, dtype))
    return treedef.unflatten(out)


def _init_one(key, name, shape, dtype):
    last = name.split("/")[-1]
    if last in ("w",) or "gain" in last:          # norm scales / gains
        return jnp.ones(shape, dtype)
    if last == "a_log":                            # mamba A init
        n = shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, shape).astype(jnp.float32)
    if last in ("b", "mu", "cm_mu", "w_bias", "u_bonus", "d_skip",
                "dt_b") or last.startswith("b"):
        if last in ("mu", "cm_mu"):
            return jnp.full(shape, 0.5, dtype)
        if last == "w_bias":
            return jnp.full(shape, -1.0, dtype)
        return jnp.zeros(shape, dtype)
    scale = 0.02
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- forward
def _embed_tokens(params, cfg: ArchConfig, tokens):
    x = params["embed"][tokens].astype(DTYPE)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def _rwkv_forward(params, cfg, embeds, remat=True):
    x = apply_norm(embeds, params["ln0"], "layernorm")

    def body(xx, lp):
        def blk(a, ll):
            return rwkv_layer(a, ll)[0]
        if remat:
            blk = jax.checkpoint(blk)
        return blk(xx, lp), None

    x, _ = scan_layers(body, x, params["layers"])
    return apply_norm(x, params["final_norm"], "layernorm")


def forward_hidden(params, cfg: ArchConfig, batch, *, remat=True,
                   chunk=1024):
    """→ final hidden states over the token positions that carry loss."""
    if cfg.family in ("dense", "moe"):
        x = _embed_tokens(params, cfg, batch["tokens"])
        return dense_forward(params, cfg, x, remat=remat, chunk=chunk)
    if cfg.family == "vlm":
        x = _embed_tokens(params, cfg, batch["tokens"])
        if cfg.n_patches and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(DTYPE), x], axis=1)
            h = dense_forward(params, cfg, x, remat=remat, chunk=chunk)
            return h[:, batch["patches"].shape[1]:]
        return dense_forward(params, cfg, x, remat=remat, chunk=chunk)
    if cfg.family == "hybrid":
        x = _embed_tokens(params, cfg, batch["tokens"])
        return hybrid_forward(params, cfg, x, remat=remat, chunk=chunk)
    if cfg.family == "ssm":
        x = _embed_tokens(params, cfg, batch["tokens"])
        return _rwkv_forward(params, cfg, x, remat=remat)
    if cfg.family == "encdec":
        enc = whisper_encode(params, cfg, batch["frames"].astype(DTYPE),
                             remat=remat, chunk=chunk)
        return whisper_decode_train(params, cfg, batch["tokens"], enc,
                                    remat=remat, chunk=chunk)
    raise ValueError(cfg.family)


def train_loss(params, cfg: ArchConfig, batch, *, remat=True, chunk=1024):
    h = forward_hidden(params, cfg, batch, remat=remat, chunk=chunk)
    lm_head = params.get("lm_head")
    return chunked_xent(h, params["embed"], batch["labels"],
                        logit_softcap=cfg.logit_softcap,
                        lm_head=lm_head,
                        valid_vocab=(cfg.vocab if cfg.vocab_padded
                                     > cfg.vocab else None))


# ---------------------------------------------------------------- serving
def prefill(params, cfg: ArchConfig, batch, *, chunk=1024):
    """Run the full prompt, return last-token logits (cache fill for the
    attention families is exercised at decode; prefill lowers the full
    forward — the compute-dominant phase)."""
    h = forward_hidden(params, cfg, batch, remat=False, chunk=chunk)
    return logits_for(h[:, -1:], params, cfg)


def decode_step(params, cfg: ArchConfig, token, cache, pos):
    """One serve step: (B,1) token + cache → (B,1,V) logits + new cache."""
    if cfg.family in ("dense", "moe", "vlm"):
        x = _embed_tokens(params, cfg, token)
        h, cache = dense_decode_step(params, cfg, x, cache, pos)
    elif cfg.family == "hybrid":
        x = _embed_tokens(params, cfg, token)
        h, cache = hybrid_decode_step(params, cfg, x, cache, pos)
    elif cfg.family == "ssm":
        x = _embed_tokens(params, cfg, token)
        x = apply_norm(x, params["ln0"], "layernorm")

        def body(xx, scanned):
            lp, l1, wkv, l2 = scanned
            y, ns = rwkv_layer(xx, lp, states=(l1, wkv, l2))
            return y, ns

        h, (n1, nwkv, n2) = scan_layers(
            body, x, (params["layers"], cache["last1"], cache["wkv"],
                      cache["last2"]))
        h = apply_norm(h, params["final_norm"], "layernorm")
        cache = {"last1": n1, "wkv": nwkv, "last2": n2}
    elif cfg.family == "encdec":
        h, cache = whisper_decode_step(params, cfg, token, cache, pos)
    else:
        raise ValueError(cfg.family)
    return logits_for(h, params, cfg), cache


# ------------------------------------------------------------------ specs
def cache_specs(cfg: ArchConfig, cell: ShapeCell, dtype=DTYPE):
    B, S = cell.global_batch, cell.seq_len
    L, KV, hd = cfg.n_layers, cfg.n_kv, cfg.head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": jax.ShapeDtypeStruct((L, B, S, KV, hd), dtype),
                "v": jax.ShapeDtypeStruct((L, B, S, KV, hd), dtype)}
    if cfg.family == "hybrid":
        Lswa = L - cfg.n_global_layers
        Lg = cfg.n_global_layers
        W = min(cfg.sliding_window, S)
        Di = cfg.ssm_expand * cfg.d_model
        N = cfg.ssm_state
        return {
            "k": jax.ShapeDtypeStruct((Lswa, B, W, KV, hd), dtype),
            "v": jax.ShapeDtypeStruct((Lswa, B, W, KV, hd), dtype),
            "conv": jax.ShapeDtypeStruct((Lswa, B, 3, Di), dtype),
            "ssm": jax.ShapeDtypeStruct((Lswa, B, Di, N), jnp.float32),
            "gk": jax.ShapeDtypeStruct((Lg, B, S, KV, hd), dtype),
            "gv": jax.ShapeDtypeStruct((Lg, B, S, KV, hd), dtype),
            "gconv": jax.ShapeDtypeStruct((Lg, B, 3, Di), dtype),
            "gssm": jax.ShapeDtypeStruct((Lg, B, Di, N), jnp.float32),
        }
    if cfg.family == "ssm":
        H = cfg.d_model // RWKV_HEAD_DIM
        return {
            "last1": jax.ShapeDtypeStruct((L, B, 1, cfg.d_model), dtype),
            "wkv": jax.ShapeDtypeStruct(
                (L, B, H, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32),
            "last2": jax.ShapeDtypeStruct((L, B, 1, cfg.d_model), dtype),
        }
    if cfg.family == "encdec":
        return {"k": jax.ShapeDtypeStruct((L, B, S, KV, hd), dtype),
                "v": jax.ShapeDtypeStruct((L, B, S, KV, hd), dtype),
                "xk": jax.ShapeDtypeStruct((L, B, S, KV, hd), dtype),
                "xv": jax.ShapeDtypeStruct((L, B, S, KV, hd), dtype)}
    raise ValueError(cfg.family)


def init_cache(cfg: ArchConfig, cell: ShapeCell, dtype=DTYPE):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, cell, dtype))


def input_specs(cfg: ArchConfig, cell: ShapeCell):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "encdec":
        St = max(128, S // 4)
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), DTYPE),
                "tokens": jax.ShapeDtypeStruct((B, St), i32),
                "labels": jax.ShapeDtypeStruct((B, St), i32)}
    if cfg.family == "vlm":
        P = cfg.n_patches
        return {"patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), DTYPE),
                "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
                "labels": jax.ShapeDtypeStruct((B, S - P), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32)}
