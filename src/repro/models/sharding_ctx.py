"""Mesh context for in-model sharding constraints.

Model code is mesh-agnostic; the launcher registers the active mesh here
before tracing, and layers call ``constrain_*`` to pin the Megatron
pattern (batch over (pod,data), heads over model) instead of leaving GSPMD
to guess.  With no mesh registered (CPU smoke tests) these are no-ops.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh(mesh):
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def _batch_axes():
    return tuple(a for a in _MESH.axis_names if a in ("pod", "data"))


def constrain(x, spec):
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain_hidden(x):
    """(B, S, D) — batch over data axes.  With the ``seq_parallel`` perf
    option on (§Perf), residual activations between blocks are ALSO
    sharded along sequence over model (Megatron SP): the TP all-reduce
    pair becomes all-gather(bf16) + reduce-scatter, and the f32 norms
    compute on 1/16 of the tokens."""
    if _MESH is None:
        return x
    from .common import perf_option
    parts = _MESH.shape.get("model", 1)
    if (perf_option("seq_parallel") and x.ndim == 3 and parts > 1
            and x.shape[1] % parts == 0 and x.shape[1] >= parts):
        return constrain(x, P(_batch_axes(), "model", None))
    return constrain(x, P(_batch_axes(), *[None] * (x.ndim - 1)))


def constrain_heads(x):
    """(B, S, H, hd) — shard heads over model when divisible."""
    if _MESH is None:
        return x
    parts = _MESH.shape.get("model", 1)
    if x.ndim == 4 and x.shape[2] % parts == 0 and x.shape[2] >= parts:
        return constrain(x, P(_batch_axes(), None, "model", None))
    return constrain(x, P(_batch_axes(), None, None, None))


def constrain_attn_q(x):
    """Query tensor: head-sharded when divisible; otherwise SEQUENCE-
    sharded over model (context parallelism — odd-head archs like
    granite-3b 24H / hymba 25H / whisper 6H would otherwise replicate the
    full f32 score tensor on every device; §Perf iteration 5)."""
    if _MESH is None:
        return x
    parts = _MESH.shape.get("model", 1)
    if x.ndim == 4 and x.shape[2] % parts == 0 and x.shape[2] >= parts:
        return constrain(x, P(_batch_axes(), None, "model", None))
    if x.ndim == 4 and x.shape[1] % parts == 0 and x.shape[1] >= parts:
        return constrain(x, P(_batch_axes(), "model", None, None))
    return constrain(x, P(_batch_axes(), None, None, None))


def constrain_ff(x):
    """(B, S, FF) — shard the expanded feature dim over model."""
    if _MESH is None:
        return x
    parts = _MESH.shape.get("model", 1)
    if x.shape[-1] % parts == 0:
        return constrain(x, P(_batch_axes(), None, "model"))
    return x


def constrain_moe_buf(buf):
    """(B, E, cap, D) dispatch buffer: batch over data axes; experts over
    model when divisible, else capacity slots over model (granite-3b's
    40 experts don't divide 16)."""
    if _MESH is None:
        return buf
    # batch-sharded ONLY: the scatter/gather around the buffer then stay
    # entirely on-device; the expert einsums pick up model-parallelism
    # from the ff-sharded expert weights (measured in EXPERIMENTS §Perf —
    # cap-sharding the buffer made the dispatch scatter cross-shard and
    # DOUBLED collective time).
    return constrain(buf, P(_batch_axes(), None, None, None))
