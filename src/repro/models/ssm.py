"""SSM families: a selective-SSM (mamba-style) branch for Hymba's hybrid
heads, and RWKV6 "Finch" (data-dependent decay linear attention).

Training uses ``associative_scan`` (mamba) / ``lax.scan`` over time (rwkv —
matrix-valued state, small carry); decode is a single-step state update, so
``long_500k`` is O(1) state per token (the sub-quadratic cells).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .common import apply_norm, scan_layers


# ----------------------------------------------------------- mamba branch
def mamba_defs(cfg: ArchConfig) -> dict:
    L, D = cfg.n_layers, cfg.d_model
    Di = cfg.ssm_expand * D
    N = cfg.ssm_state
    return {
        "in_proj": ((L, D, 2 * Di), "col"),       # x and gate z
        "conv_w": ((L, 4, Di), "rep"),            # depthwise causal conv
        "dt_a": ((L, Di, 64), "rep"),             # low-rank Δ (mamba dt_rank)
        "dt_proj": ((L, 64, Di), "rep"),
        "dt_b": ((L, Di), "rep"),
        "bc_w": ((L, Di, 2 * N), "rep"),
        "a_log": ((L, Di, N), "rep"),
        "d_skip": ((L, Di), "rep"),
        "out_proj": ((L, Di, D), "row"),
    }


def _causal_conv(x, w):
    """x (B,S,Di), w (4,Di) depthwise: y_t = Σ_j w_j · x_{t-3+j}."""
    pads = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    return sum(pads[:, j:j + x.shape[1]] * w[j] for j in range(4))


def mamba_branch(x, lp, cfg: ArchConfig, *, conv_state=None, ssm_state=None):
    """x (B,S,D) → (B,S,D).  With states given (decode): S must be 1 and
    (y, new_conv_state, new_ssm_state) is returned."""
    B, S, D = x.shape
    Di = cfg.ssm_expand * D
    N = cfg.ssm_state
    xz = x @ lp["in_proj"]
    xi, z = xz[..., :Di], xz[..., Di:]
    decode = conv_state is not None
    if decode:
        window = jnp.concatenate([conv_state, xi], axis=1)   # (B,4,Di)
        xi = sum(window[:, j] * lp["conv_w"][j] for j in range(4))[:, None]
        new_conv = window[:, 1:]
    else:
        xi = _causal_conv(xi, lp["conv_w"])
        new_conv = None
    xi = jax.nn.silu(xi)
    dt = jax.nn.softplus((xi @ lp["dt_a"]) @ lp["dt_proj"]
                         + lp["dt_b"])                       # (B,S,Di)
    bc = xi @ lp["bc_w"]
    Bm, Cm = bc[..., :N], bc[..., N:]                        # (B,S,N)
    from .common import perf_option
    sdt = jnp.dtype(perf_option("ssm_scan_dtype"))           # §Perf knob
    A = -jnp.exp(lp["a_log"].astype(jnp.float32)).astype(sdt)  # (Di,N)
    dA = jnp.exp(dt.astype(sdt)[..., None] * A)              # (B,S,Di,N)
    dBx = (dt * xi).astype(sdt)[..., None] * \
        Bm.astype(sdt)[..., None, :]                         # (B,S,Di,N)
    if decode:
        h = (dA[:, 0].astype(jnp.float32) * ssm_state
             + dBx[:, 0].astype(jnp.float32))                # (B,Di,N)
        y = (h * Cm.astype(jnp.float32)[:, 0, None, :]).sum(-1)[:, None]
        new_ssm = h
    elif perf_option("ssm_backend") == "pallas":
        # fused Pallas kernel: hidden states never reach HBM (§Perf —
        # production path would emit (N, Di) layout from the projections
        # directly; the transposes here are the integration shim)
        from repro.kernels.selective_scan import selective_scan
        y = selective_scan(dA.transpose(0, 1, 3, 2),
                           dBx.transpose(0, 1, 3, 2),
                           Cm.astype(jnp.float32))
        new_ssm = None
    else:
        def combine(a, b):
            return a[0] * b[0], b[0] * a[1] + b[1]
        _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        y = (hs.astype(jnp.float32)
             * Cm.astype(jnp.float32)[..., None, :]).sum(-1)
        new_ssm = None
    y = y.astype(x.dtype) + xi * lp["d_skip"]
    y = (y * jax.nn.silu(z)) @ lp["out_proj"]
    if decode:
        return y, new_conv, new_ssm
    return y


# ------------------------------------------------------------------ RWKV6
RWKV_HEAD_DIM = 64


def rwkv_defs(cfg: ArchConfig) -> dict:
    L, D, FF = cfg.n_layers, cfg.d_model, cfg.d_ff
    lora = 64
    return {
        "ln1": {"w": ((L, D), "rep"), "b": ((L, D), "rep")},
        "ln2": {"w": ((L, D), "rep"), "b": ((L, D), "rep")},
        # time mix: token-shift interpolation weights per r/k/v/w/g
        "mu": ((L, 5, D), "rep"),
        "wr": ((L, D, D), "col"),
        "wk": ((L, D, D), "col"),
        "wv": ((L, D, D), "col"),
        "wg": ((L, D, D), "col"),
        # data-dependent decay (Finch): low-rank w = exp(-exp(lora(x)))
        "w_lora_a": ((L, D, lora), "rep"),
        "w_lora_b": ((L, lora, D), "rep"),
        "w_bias": ((L, D), "rep"),
        "u_bonus": ((L, D), "rep"),
        "wo": ((L, D, D), "row"),
        # channel mix
        "cm_mu": ((L, 2, D), "rep"),
        "cm_k": ((L, D, FF), "col"),
        "cm_v": ((L, FF, D), "row"),
        "cm_r": ((L, D, D), "col"),
    }


def _token_shift(x, last=None):
    """x (B,S,D) → previous-token tensor; ``last`` (B,1,D) for decode."""
    if last is not None:
        return last
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _wkv6(r, k, v, w, u, state=None):
    """RWKV6 core. r/k/v/w (B,S,H,hd); u (H,hd).
    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ;  y_t = r_t·(S_{t-1} + diag(u)k_t v_tᵀ)
    state (B,H,hd,hd) for decode; returns (y, new_state)."""
    B, S, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, t):
        rt, kt, vt, wt = t
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
               for a in (r, k, v, w))
    new_state, ys = scan_layers(step, state, xs)
    return ys.transpose(1, 0, 2, 3), new_state


def rwkv_time_mix(x, lp, *, last=None, state=None):
    B, S, D = x.shape
    H = D // RWKV_HEAD_DIM
    xp = _token_shift(x, last)
    mixed = [x + lp["mu"][i] * (xp - x) for i in range(5)]
    r = (mixed[0] @ lp["wr"]).reshape(B, S, H, RWKV_HEAD_DIM)
    k = (mixed[1] @ lp["wk"]).reshape(B, S, H, RWKV_HEAD_DIM)
    v = (mixed[2] @ lp["wv"]).reshape(B, S, H, RWKV_HEAD_DIM)
    g = jax.nn.silu(mixed[4] @ lp["wg"])
    wdec = lp["w_bias"] + (jnp.tanh(mixed[3] @ lp["w_lora_a"])
                           @ lp["w_lora_b"])
    w = jnp.exp(-jnp.exp(wdec.astype(jnp.float32))).reshape(
        B, S, H, RWKV_HEAD_DIM)
    u = lp["u_bonus"].reshape(H, RWKV_HEAD_DIM)
    y, new_state = _wkv6(r, k, v, w, u, state)
    y = y.astype(x.dtype).reshape(B, S, D) * g
    return y @ lp["wo"], new_state


def rwkv_channel_mix(x, lp, *, last=None):
    xp = _token_shift(x, last)
    xk = x + lp["cm_mu"][0] * (xp - x)
    xr = x + lp["cm_mu"][1] * (xp - x)
    k = jnp.square(jax.nn.relu(xk @ lp["cm_k"]))
    return jax.nn.sigmoid(xr @ lp["cm_r"]) * (k @ lp["cm_v"])


def rwkv_layer(x, lp, *, states=None):
    """states = (last1, wkv_state, last2) for decode (S=1)."""
    h = apply_norm(x, lp["ln1"], "layernorm")
    if states is None:
        att, _ = rwkv_time_mix(h, lp)
        x = x + att
        h2 = apply_norm(x, lp["ln2"], "layernorm")
        x = x + rwkv_channel_mix(h2, lp)
        return x, None
    last1, wkv, last2 = states
    att, new_wkv = rwkv_time_mix(h, lp, last=last1, state=wkv)
    x = x + att
    h2 = apply_norm(x, lp["ln2"], "layernorm")
    x = x + rwkv_channel_mix(h2, lp, last=last2)
    return x, (h, new_wkv, h2)
