"""GCN, GIN (paper §6.5) and GAT with pluggable sparse aggregation.

GCN/GIN take a `spmm: (n, d) -> (n, d)` closure over the graph — either a
ParamSpMM operator (decider-configured) or a baseline path — so "embed
ParamSpMM into GNN training" is literally swapping this callable, as the
paper does with its PyTorch extension.  Closures that additionally expose
``.fused(B, scale=, bias=, activation=)`` (ParamSpMM / ParamSpMMOperator
/ DistGraph) get each GCN layer's bias + ReLU handed to the SpMM's fused
epilogue — one kernel per aggregation on the Pallas backend.  GAT
instead takes the fused message closure `msg: (Q, K, Vf) -> (n, d)`
built by ``core.engine.make_gat_message_fn`` (two kernels: SDDMM→softmax
stats, prologue SpMM), mirroring HGL-proto's GSDDMM/GSPMM operator pair.

The distributed operators plug into the same seams with global shapes:
``repro.dist.DistGraph`` is a `(n, d) -> (n, d)` spmm closure (with the
same ``.fused`` epilogue surface) and its ``.gat_message`` a message
closure accepting the same single-head `(n, d)` or multi-head
`(H, n, d)` stacks as the single-device message fn — the models never
see the mesh, the partitioning, the per-shard configs, or whether the
halo gather is overlapped with compute (`apps/gnn.py --partitions N
[--heads H] [--overlap]` wires them in; docs/DISTRIBUTED.md walks
through scaling a GAT to N shards).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, fan_in, fan_out):
    scale = jnp.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


# -------------------------------------------------------------------- GCN
def init_gcn(key, layer_dims):
    """layer_dims e.g. [16, 64, 64, 64, 64, 16] → 5 layers (paper setup)."""
    params = []
    for i in range(len(layer_dims) - 1):
        key, k1 = jax.random.split(key)
        params.append({
            "w": _dense_init(k1, layer_dims[i], layer_dims[i + 1]),
            "b": jnp.zeros(layer_dims[i + 1], jnp.float32),
        })
    return params


def gcn_forward(params, X, spmm):
    """One GCN layer is ``relu(Â·H·W + b)``.  When the aggregation closure
    exposes the epilogue-fusion surface (``spmm.fused`` — ParamSpMM /
    ParamSpMMOperator / DistGraph), the layer reassociates to
    ``Â·(H·W)`` and hands bias + activation to the SpMM epilogue: the
    whole aggregation step is ONE kernel on the Pallas backend (the
    bias/ReLU passes ride the VMEM-resident output block) instead of
    kernel + 2–3 XLA elementwise passes over the (n, d) output."""
    fused = getattr(spmm, "fused", None)
    h = X
    for i, layer in enumerate(params):
        last = i == len(params) - 1
        w = layer["w"]
        # fuse only when transform-then-aggregate doesn't widen the SpMM:
        # the epilogue needs the SpMM last, i.e. Â·(H·W) — a win (and the
        # one-kernel layer) for d_out ≤ d_in, a wider gather otherwise
        if fused is not None and w.shape[1] <= w.shape[0]:
            h = fused(h @ w, bias=layer["b"],
                      activation="none" if last else "relu")
        else:
            h = spmm(h) @ w + layer["b"]               # Â·H·W
            if not last:
                h = jax.nn.relu(h)
    return h


# -------------------------------------------------------------------- GIN
def init_gin(key, layer_dims, mlp_hidden_mult: int = 1):
    params = []
    for i in range(len(layer_dims) - 1):
        key, k1, k2 = jax.random.split(key, 3)
        hid = layer_dims[i + 1] * mlp_hidden_mult
        params.append({
            "eps": jnp.zeros((), jnp.float32),
            "w1": _dense_init(k1, layer_dims[i], hid),
            "b1": jnp.zeros(hid, jnp.float32),
            "w2": _dense_init(k2, hid, layer_dims[i + 1]),
            "b2": jnp.zeros(layer_dims[i + 1], jnp.float32),
        })
    return params


def gin_forward(params, X, spmm):
    """GIN aggregation is ``(1+ε)h + A·h``.  When the aggregation closure
    exposes a residual-capable fused epilogue (``spmm.fused(...,
    residual=)`` — ParamSpMM / ParamSpMMOperator), the ``(1+ε)h`` term is
    handed to the SpMM epilogue as the dense residual addend: the whole
    aggregation is ONE kernel on the Pallas backend — the addend rides
    the VMEM-resident output block — instead of kernel + an XLA add pass
    over the (n, d) output."""
    import inspect
    fused = getattr(spmm, "fused", None)
    if fused is not None:
        try:
            if "residual" not in inspect.signature(fused).parameters:
                fused = None                # e.g. DistGraph: no residual yet
        except (TypeError, ValueError):
            fused = None
    h = X
    for i, layer in enumerate(params):
        if fused is not None:
            agg = fused(h, residual=(1.0 + layer["eps"]) * h)
        else:
            agg = (1.0 + layer["eps"]) * h + spmm(h)   # (1+ε)h + A·h
        z = jax.nn.relu(agg @ layer["w1"] + layer["b1"])
        h = z @ layer["w2"] + layer["b2"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


# -------------------------------------------------------------------- GAT
def init_gat(key, layer_dims, att_dim: int | None = None, heads: int = 1):
    """Dot-product attention GAT: per layer Wq/Wk project into the
    attention space (att_dim per head, default = per-head output dim), Wv
    transforms the message features.

    Multi-head (``heads > 1``) follows the standard GAT scheme: hidden
    layers concatenate the per-head outputs (layer dim must divide by
    ``heads``), the final layer averages full-width heads.
    """
    params = []
    L = len(layer_dims) - 1
    for i in range(L):
        key, kq, kk, kv = jax.random.split(key, 4)
        out = layer_dims[i + 1]
        concat = heads > 1 and i < L - 1
        if concat and out % heads:
            raise ValueError(f"layer dim {out} not divisible by {heads} heads")
        dv = out // heads if concat else out
        da = att_dim or dv
        params.append({
            "wq": _dense_init(kq, layer_dims[i], heads * da),
            "wk": _dense_init(kk, layer_dims[i], heads * da),
            "wv": _dense_init(kv, layer_dims[i], heads * dv),
            "b": jnp.zeros(out, jnp.float32),
        })
    return params


def gat_forward(params, X, gat_msg, heads: int = 1):
    """h'_i = Σ_j α_ij · (h_j·Wv), α = softmax_j(LeakyReLU(q_i·k_j/√d)).

    With ``heads > 1`` the projections are split into (H, n, d_head)
    stacks and handed to ``gat_msg`` as one batch — the message fn (see
    ``core.engine.make_gat_message_fn``) runs every head through a single
    head-tiled kernel call, so the layer compiles once however many heads.
    """
    h = X
    L = len(params)
    for i, layer in enumerate(params):
        q, k, v = h @ layer["wq"], h @ layer["wk"], h @ layer["wv"]
        if heads == 1:
            h = gat_msg(q, k, v) + layer["b"]
        else:
            n = h.shape[0]
            split = lambda m: m.reshape(n, heads, -1).transpose(1, 0, 2)
            msg = gat_msg(split(q), split(k), split(v))    # (H, n, dv)
            if i < L - 1:                                  # concat heads
                h = msg.transpose(1, 0, 2).reshape(n, -1) + layer["b"]
            else:                                          # average heads
                h = msg.mean(axis=0) + layer["b"]
        if i < L - 1:
            h = jax.nn.relu(h)
    return h


# ------------------------------------------------------------------ loss
def node_ce_loss(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def accuracy(logits, labels, mask):
    pred = logits.argmax(-1)
    return ((pred == labels) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
