"""GCN and GIN (paper §6.5) with pluggable SpMM aggregation.

The aggregation `spmm: (n, d) -> (n, d)` is a closure over the graph —
either a ParamSpMM operator (decider-configured) or a baseline path —
so "embed ParamSpMM into GNN training" is literally swapping this
callable, as the paper does with its PyTorch extension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, fan_in, fan_out):
    scale = jnp.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


# -------------------------------------------------------------------- GCN
def init_gcn(key, layer_dims):
    """layer_dims e.g. [16, 64, 64, 64, 64, 16] → 5 layers (paper setup)."""
    params = []
    for i in range(len(layer_dims) - 1):
        key, k1 = jax.random.split(key)
        params.append({
            "w": _dense_init(k1, layer_dims[i], layer_dims[i + 1]),
            "b": jnp.zeros(layer_dims[i + 1], jnp.float32),
        })
    return params


def gcn_forward(params, X, spmm):
    h = X
    for i, layer in enumerate(params):
        h = spmm(h) @ layer["w"] + layer["b"]          # Â·H·W
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


# -------------------------------------------------------------------- GIN
def init_gin(key, layer_dims, mlp_hidden_mult: int = 1):
    params = []
    for i in range(len(layer_dims) - 1):
        key, k1, k2 = jax.random.split(key, 3)
        hid = layer_dims[i + 1] * mlp_hidden_mult
        params.append({
            "eps": jnp.zeros((), jnp.float32),
            "w1": _dense_init(k1, layer_dims[i], hid),
            "b1": jnp.zeros(hid, jnp.float32),
            "w2": _dense_init(k2, hid, layer_dims[i + 1]),
            "b2": jnp.zeros(layer_dims[i + 1], jnp.float32),
        })
    return params


def gin_forward(params, X, spmm):
    h = X
    for i, layer in enumerate(params):
        agg = (1.0 + layer["eps"]) * h + spmm(h)       # (1+ε)h + A·h
        z = jax.nn.relu(agg @ layer["w1"] + layer["b1"])
        h = z @ layer["w2"] + layer["b2"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


# ------------------------------------------------------------------ loss
def node_ce_loss(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def accuracy(logits, labels, mask):
    pred = logits.argmax(-1)
    return ((pred == labels) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
