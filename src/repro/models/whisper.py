"""Whisper-tiny backbone: encoder-decoder transformer with layernorm,
learned positional embeddings, GELU MLPs, and decoder cross-attention.
The conv audio frontend is a STUB per the assignment — ``input_specs()``
supplies precomputed frame embeddings (B, S_audio, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .common import apply_norm, plain_mlp, scan_layers, NEG_INF
from .transformer import _attn_block, chunked_attention

MAX_POS = 65536          # learned positional table size (structural)


def _attn_defs(L, D, qd, kvd, prefix=""):
    return {
        f"{prefix}wq": ((L, D, qd), "col"),
        f"{prefix}wk": ((L, D, kvd), "col"),
        f"{prefix}wv": ((L, D, kvd), "col"),
        f"{prefix}wo": ((L, qd, D), "row"),
        f"{prefix}bq": ((L, qd), "col_b"),
        f"{prefix}bv": ((L, kvd), "col_b"),
        f"{prefix}bo": ((L, D), "rep"),
    }


def _ln(L, D):
    return {"w": ((L, D), "rep"), "b": ((L, D), "rep")}


def whisper_model_defs(cfg: ArchConfig) -> dict:
    D, qd, kvd, FF = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    enc = {"ln1": _ln(Le, D), "ln2": _ln(Le, D),
           "w1": ((Le, D, FF), "col"), "b1": ((Le, FF), "col_b"),
           "w2": ((Le, FF, D), "row"), "b2": ((Le, D), "rep")}
    enc.update(_attn_defs(Le, D, qd, kvd))
    dec = {"ln1": _ln(Ld, D), "ln2": _ln(Ld, D), "ln3": _ln(Ld, D),
           "w1": ((Ld, D, FF), "col"), "b1": ((Ld, FF), "col_b"),
           "w2": ((Ld, FF, D), "row"), "b2": ((Ld, D), "rep")}
    dec.update(_attn_defs(Ld, D, qd, kvd))
    dec.update(_attn_defs(Ld, D, qd, kvd, prefix="x"))     # cross-attn
    return {
        "embed": ((cfg.vocab_padded, D), "embed"),
        "pos_enc": ((MAX_POS, D), "rep_big"),
        "pos_dec": ((MAX_POS, D), "rep_big"),
        "enc_final": _ln(1, D),
        "dec_final": _ln(1, D),
        "enc": enc,
        "dec": dec,
    }


def _mha(h, lp, prefix, cfg, *, kv_src=None, causal, cache=None, pos=None,
         chunk=1024):
    """Self- or cross-attention with biases (whisper has q/v/o biases)."""
    B, Sq, D = h.shape
    src = h if kv_src is None else kv_src
    q = (h @ lp[f"{prefix}wq"] + lp[f"{prefix}bq"]).reshape(
        B, Sq, cfg.n_heads, cfg.head_dim)
    k = (src @ lp[f"{prefix}wk"]).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    v = (src @ lp[f"{prefix}wv"] + lp[f"{prefix}bv"]).reshape(
        B, -1, cfg.n_kv, cfg.head_dim)
    new_cache = None
    if cache is not None:                        # decode self-attn
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
        new_cache = (ck, cv)
        out = _attn_block(q, ck, cv, causal=True, window=0,
                          attn_softcap=0.0, local_flag=None, q_offset=pos)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=0,
                                attn_softcap=0.0, chunk=chunk)
    out = out.reshape(B, Sq, cfg.q_dim)
    return out @ lp[f"{prefix}wo"] + lp[f"{prefix}bo"], new_cache


def whisper_encode(params, cfg: ArchConfig, frames, *, remat=True,
                   chunk=1024):
    """frames (B, Sa, D) stub embeddings → encoder states."""
    Sa = frames.shape[1]
    x = frames + params["pos_enc"][:Sa][None]

    def body(xx, lp):
        def blk(a, ll):
            h, _ = _mha(apply_norm(a, ll["ln1"], "layernorm"), ll, "", cfg,
                        causal=False, chunk=chunk)
            a = a + h
            m = plain_mlp(apply_norm(a, ll["ln2"], "layernorm"),
                          ll["w1"], ll["b1"], ll["w2"], ll["b2"])
            return a + m
        if remat:
            blk = jax.checkpoint(blk)
        return blk(xx, lp), None

    x, _ = scan_layers(body, x, params["enc"])
    f = {"w": params["enc_final"]["w"][0], "b": params["enc_final"]["b"][0]}
    return apply_norm(x, f, "layernorm")


def whisper_decode_train(params, cfg: ArchConfig, tokens, enc_states, *,
                         remat=True, chunk=1024):
    St = tokens.shape[1]
    x = params["embed"][tokens] + params["pos_dec"][:St][None]

    def body(xx, lp):
        def blk(a, ll):
            h, _ = _mha(apply_norm(a, ll["ln1"], "layernorm"), ll, "", cfg,
                        causal=True, chunk=chunk)
            a = a + h
            h, _ = _mha(apply_norm(a, ll["ln2"], "layernorm"), ll, "x", cfg,
                        kv_src=enc_states, causal=False, chunk=chunk)
            a = a + h
            m = plain_mlp(apply_norm(a, ll["ln3"], "layernorm"),
                          ll["w1"], ll["b1"], ll["w2"], ll["b2"])
            return a + m
        if remat:
            blk = jax.checkpoint(blk)
        return blk(xx, lp), None

    x, _ = scan_layers(body, x, params["dec"])
    f = {"w": params["dec_final"]["w"][0], "b": params["dec_final"]["b"][0]}
    return apply_norm(x, f, "layernorm")


def whisper_decode_step(params, cfg: ArchConfig, token, cache, pos):
    """One decoder token. cache: {"k","v" (Ld,B,St,KV,hd) self-attn,
    "xk","xv" (Ld,B,Sa,KV,hd) precomputed cross-attn K/V}."""
    x = params["embed"][token] + params["pos_dec"][pos][None, None]

    def body(xx, scanned):
        lp, ck, cv, xk, xv = scanned
        h, (nk, nv) = _mha(apply_norm(xx, lp["ln1"], "layernorm"), lp, "",
                           cfg, causal=True, cache=(ck, cv), pos=pos)
        xx = xx + h
        # cross-attn against precomputed encoder K/V (all positions valid)
        B = xx.shape[0]
        q = (apply_norm(xx, lp["ln2"], "layernorm") @ lp["xwq"]
             + lp["xbq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        from .hybrid import decode_attn
        h = decode_attn(q, xk, xv, jnp.int32(xk.shape[1] - 1))
        h = h.reshape(B, 1, cfg.q_dim) @ lp["xwo"] + lp["xbo"]
        xx = xx + h
        m = plain_mlp(apply_norm(xx, lp["ln3"], "layernorm"),
                      lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return xx + m, (nk, nv)

    x, (nk, nv) = scan_layers(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    f = {"w": params["dec_final"]["w"][0], "b": params["dec_final"]["b"][0]}
    return apply_norm(x, f, "layernorm"), {"k": nk, "v": nv,
                                           "xk": cache["xk"],
                                           "xv": cache["xv"]}
