"""Shared transformer building blocks (pure JAX, bf16-friendly)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38          # f32-safe mask value

# Cost mode: the dry-run's roofline compiles set this so that layer/time
# scans UNROLL — XLA's cost_analysis counts a while-loop body once
# regardless of trip count, so per-layer cost is only measurable from
# unrolled small-L variants (DESIGN.md §6).  Never on in real execution.
_COST_MODE = [False]


def set_cost_mode(on: bool):
    _COST_MODE[0] = bool(on)


def cost_mode() -> bool:
    return _COST_MODE[0]


def scan_layers(body, init, xs, length=None):
    """lax.scan for layer/time stacks; unrolled in cost mode."""
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if cost_mode() else 1)


# Perf options (EXPERIMENTS.md §Perf): paper-faithful/naive defaults; the
# hillclimbed variants are switched on per-run by the launcher/dry-run so
# baseline and optimized lowerings stay independently reproducible.
PERF_DEFAULTS = {
    "moe_dispatch": "global",      # global cumsum | "batched" shard-local
    "ssm_scan_dtype": "float32",   # mamba recurrence precision
    "remat_policy": "full",        # full recompute | "dots" save matmuls
    "seq_parallel": False,         # Megatron SP residual activations
    "bf16_norm_grad": False,       # bf16 dx cotangent through RMSNorm
    "ssm_backend": "xla",          # mamba scan: xla assoc-scan | pallas
}
_PERF = dict(PERF_DEFAULTS)


def set_perf_options(**kw):
    for k, v in kw.items():
        if k in _PERF and v is not None:
            _PERF[k] = v


def reset_perf_options():
    _PERF.update(PERF_DEFAULTS)


def perf_option(key: str):
    return _PERF[key]


def rms_norm(x, w, eps=1e-6, plus_one=False):
    from .common import perf_option  # self-import safe at call time
    if perf_option("bf16_norm_grad") and x.dtype == jnp.bfloat16:
        return _rms_norm_bf16grad(x, w, eps, plus_one)
    return _rms_norm_impl(x, w, eps, plus_one)


def _rms_norm_impl(x, w, eps=1e-6, plus_one=False):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rms_norm_bf16grad(x, w, eps, plus_one):
    """RMSNorm whose input cotangent is emitted in bf16 (§Perf: keeps the
    tensor-parallel dx all-reduces in bf16 instead of the f32 that XLA
    otherwise hoists across the norm's f32 compute region)."""
    return _rms_norm_impl(x, w, eps, plus_one)


def _rmsn_fwd(x, w, eps, plus_one):
    return _rms_norm_impl(x, w, eps, plus_one), (x, w)


def _rmsn_bwd(eps, plus_one, res, g):
    x, w = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one \
        else w.astype(jnp.float32)
    gy = g32 * scale
    d = x.shape[-1]
    dx = inv * (gy - x32 * inv * inv
                * jnp.mean(gy * x32, axis=-1, keepdims=True))
    dw = jnp.sum(g32 * x32 * inv,
                 axis=tuple(range(x.ndim - 1))).astype(w.dtype)
    return dx.astype(x.dtype), dw


_rms_norm_bf16grad.defvjp(_rmsn_fwd, _rmsn_bwd)


def layer_norm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, kind="rmsnorm", plus_one=False):
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"], plus_one=plus_one)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap)


# -------------------------------------------------------------------- RoPE
def rope_tables(positions, head_dim: int, fraction: float = 1.0,
                base: float = 10000.0):
    """cos/sin tables (..., rot_half) for neox-style rotate-half RoPE.
    ``fraction < 1`` = partial rotary (chatglm3's 2d RoPE rotates half)."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (base ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., rot/2)
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x, cos, sin, rot: int):
    """x (B, S, H, hd); cos/sin (B?, S, rot/2) broadcast over heads."""
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    c = cos[..., None, :].astype(x.dtype)    # (B, S, 1, rot/2)
    s = sin[..., None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out, xp], axis=-1)


# --------------------------------------------------------------- attention
def gqa_attention(q, k, v, *, causal=True, window: int = 0,
                  attn_softcap: float = 0.0, q_offset=None):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd). GQA via head-group reshape.

    ``q_offset``: absolute position of q[0] (decode: Sk-1); default assumes
    q and k start together (training/prefill).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) / jnp.sqrt(
        jnp.float32(hd)).astype(q.dtype)
    if attn_softcap > 0:
        scores = softcap(scores.astype(jnp.float32), attn_softcap)
    scores = scores.astype(jnp.float32)
    qpos = jnp.arange(Sq) + (q_offset if q_offset is not None else 0)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


# --------------------------------------------------------------------- MLP
def gated_mlp(x, wg, wu, wd, act="silu"):
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    return (a(x @ wg) * (x @ wu)) @ wd


def plain_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2


# -------------------------------------------------------------------- loss
def softmax_xent(logits, labels, mask=None):
    """Mean next-token CE. logits (B,S,V) any dtype → f32 reduction."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
