"""Hymba-style hybrid layer: attention heads and a mamba SSM branch run in
PARALLEL on the same normed input, outputs fused by learned per-branch
gains (Hymba §2: "parallel attn+mamba heads"; meta-tokens omitted — noted
in DESIGN.md).  The stack is heterogeneous: the first ``L - n_global``
layers use sliding-window attention (ring-buffer KV at decode), the last
``n_global_layers`` attend globally (full KV) — so ``long_500k`` decode
holds O(window) state for most layers and is sub-quadratic end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .common import (apply_norm, apply_rope, gated_mlp, rope_tables,
                     scan_layers, NEG_INF)
from .ssm import mamba_branch, mamba_defs
from .transformer import _attn_block, chunked_attention


def _branch_defs(cfg: ArchConfig, L: int) -> dict:
    D = cfg.d_model
    sub = cfg.replace(n_layers=L)
    defs = {
        "ln1": {"w": ((L, D), "rep")},
        "ln2": {"w": ((L, D), "rep")},
        "wq": ((L, D, cfg.q_dim), "col"),
        "wk": ((L, D, cfg.kv_dim), "col"),
        "wv": ((L, D, cfg.kv_dim), "col"),
        "wo": ((L, cfg.q_dim, D), "row"),
        "attn_gain": ((L, D), "rep"),
        "ssm_gain": ((L, D), "rep"),
        "wg": ((L, D, cfg.d_ff), "col"),
        "wu": ((L, D, cfg.d_ff), "col"),
        "wd": ((L, cfg.d_ff, D), "row"),
    }
    defs.update(mamba_defs(sub))
    return defs


def hybrid_model_defs(cfg: ArchConfig) -> dict:
    n_swa = cfg.n_layers - cfg.n_global_layers
    return {
        "embed": ((cfg.vocab_padded, cfg.d_model), "embed"),
        "final_norm": {"w": ((cfg.d_model,), "rep")},
        "layers": _branch_defs(cfg, n_swa),        # sliding-window stack
        "glayers": _branch_defs(cfg, cfg.n_global_layers),
    }


def decode_attn(q, ck, cv, valid_upto):
    """Ring/flat decode attention: all cache slots ≤ valid_upto are live
    (slot order is irrelevant to the softmax sum)."""
    B, _, H, hd = q.shape
    Sk, KV = ck.shape[1], ck.shape[2]
    if KV != H:
        ck = jnp.repeat(ck, H // KV, axis=2)
        cv = jnp.repeat(cv, H // KV, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, ck).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    mask = jnp.arange(Sk) <= valid_upto
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, cv)


def hybrid_layer(x, lp, cfg: ArchConfig, *, cos, sin, rot, window,
                 cache=None, pos=None, write=None, chunk=1024):
    """window=0 → global layer.  cache=(k,v,conv,ssm) → decode (S=1)."""
    B, Sq, _ = x.shape
    h = apply_norm(x, lp["ln1"], cfg.norm)

    from .sharding_ctx import constrain_attn_q, constrain_heads
    q = constrain_attn_q(
        (h @ lp["wq"]).reshape(B, Sq, cfg.n_heads, cfg.head_dim))
    k = constrain_heads(
        (h @ lp["wk"]).reshape(B, Sq, cfg.n_kv, cfg.head_dim))
    v = constrain_heads(
        (h @ lp["wv"]).reshape(B, Sq, cfg.n_kv, cfg.head_dim))
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)
    new_cache = None
    if cache is not None:
        ck, cv, conv_s, ssm_s = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, write, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, write, axis=1)
        valid = jnp.minimum(pos, ck.shape[1] - 1)
        attn = decode_attn(q, ck, cv, valid)
    else:
        attn = chunked_attention(q, k, v, causal=True, window=window,
                                 attn_softcap=0.0, chunk=chunk)
    attn = attn.reshape(B, Sq, cfg.q_dim) @ lp["wo"]

    if cache is not None:
        ssm, new_conv, new_ssm = mamba_branch(h, lp, cfg,
                                              conv_state=conv_s,
                                              ssm_state=ssm_s)
        new_cache = (ck, cv, new_conv, new_ssm)
    else:
        ssm = mamba_branch(h, lp, cfg)

    x = x + attn * lp["attn_gain"] + ssm * lp["ssm_gain"]
    h2 = apply_norm(x, lp["ln2"], cfg.norm)
    return x + gated_mlp(h2, lp["wg"], lp["wu"], lp["wd"], cfg.act), new_cache


def _scan_stack(x, stack, cfg, *, cos, sin, rot, window, remat, chunk):
    def body(xx, lp):
        def blk(a, ll):
            return hybrid_layer(a, ll, cfg, cos=cos, sin=sin, rot=rot,
                                window=window, chunk=chunk)[0]
        if remat:
            blk = jax.checkpoint(blk)
        return blk(xx, lp), None

    x, _ = scan_layers(body, x, stack)
    return x


def hybrid_forward(params, cfg: ArchConfig, embeds, *, remat=True,
                   chunk=1024):
    S = embeds.shape[1]
    cos, sin, rot = rope_tables(jnp.arange(S)[None, :], cfg.head_dim,
                                cfg.rope_fraction, cfg.rope_base)
    x = _scan_stack(embeds, params["layers"], cfg, cos=cos, sin=sin,
                    rot=rot, window=cfg.sliding_window, remat=remat,
                    chunk=chunk)
    x = _scan_stack(x, params["glayers"], cfg, cos=cos, sin=sin, rot=rot,
                    window=0, remat=remat, chunk=chunk)
    return apply_norm(x, params["final_norm"], cfg.norm)


def hybrid_decode_step(params, cfg: ArchConfig, token_embed, cache, pos):
    """cache: SWA ring stacks ("k","v" (Lswa,B,window,KV,hd), "conv",
    "ssm") + global stacks ("gk","gv" (Lg,B,S,KV,hd), "gconv","gssm")."""
    cos, sin, rot = rope_tables(pos[None, None], cfg.head_dim,
                                cfg.rope_fraction, cfg.rope_base)

    def make_body(ring: bool):
        def body(x, scanned):
            lp, ck, cv, conv_s, ssm_s = scanned
            write = pos % ck.shape[1] if ring else pos
            y, nc = hybrid_layer(x, lp, cfg, cos=cos, sin=sin, rot=rot,
                                 window=0, cache=(ck, cv, conv_s, ssm_s),
                                 pos=pos, write=write)
            return y, nc
        return body

    x, (nk, nv, nconv, nssm) = scan_layers(
        make_body(True), token_embed,
        (params["layers"], cache["k"], cache["v"], cache["conv"],
         cache["ssm"]))
    x, (gk, gv, gconv, gssm) = scan_layers(
        make_body(False), x,
        (params["glayers"], cache["gk"], cache["gv"], cache["gconv"],
         cache["gssm"]))
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return x, {"k": nk, "v": nv, "conv": nconv, "ssm": nssm,
               "gk": gk, "gv": gv, "gconv": gconv, "gssm": gssm}
