"""Bucket-keyed steering-pack cache.

A cache entry (``BucketPack``) is everything request-independent about a
bucket: the decider/cost-model-picked ⟨W,F,V,S,B⟩ config and the static
``PackGeom`` derived from it.  The pick runs ONCE per bucket — on the
first batch that lands in it, using that batch's union subgraph as the
feature source — and is then amortized across every request the bucket
ever serves (the compiled forward is keyed on the same ``PackGeom``, so
a cache hit also means a jit cache hit).

Hits/misses/evictions are tracked in plain attributes (always on, the
bench reads them) and mirrored into ``repro.obs`` counters
(``serve_cache_hits_total`` / ``serve_cache_misses_total`` /
``serve_cache_evictions_total``) when tracing is active.  Capacity-bounded
LRU: evicting a bucket drops its config pick, not correctness — the next
miss re-picks.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

from repro.core.pcsr import SpMMConfig
from repro.core.sparse import CSRMatrix
from repro.obs import metrics as _metrics

from .bucket import PackGeom, ShapeBucket


@dataclass(frozen=True)
class BucketPack:
    """Amortized per-bucket state: the picked config + static geometry."""

    bucket: ShapeBucket
    config: SpMMConfig
    geom: PackGeom


class SteeringPackCache:
    """LRU cache ``ShapeBucket → BucketPack``.

    ``dim`` is the widest layer of the served model (the config pick's
    embedding-dim argument); ``op`` steers the cost model ("spmm" for
    GCN/GIN, "gat" for attention); ``decider`` short-circuits the
    cost-model sweep with a trained prediction.
    """

    def __init__(self, *, dim: int, capacity: int = 8, op: str = "spmm",
                 heads: int = 1, decider=None):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.dim = dim
        self.capacity = capacity
        self.op = op
        self.heads = heads
        self.decider = decider
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: OrderedDict[ShapeBucket, BucketPack] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, bucket: ShapeBucket, csr: CSRMatrix) -> BucketPack:
        """The bucket's pack, picking a config from ``csr`` on a miss."""
        entry = self._entries.get(bucket)
        if entry is not None:
            self._entries.move_to_end(bucket)
            self.hits += 1
            _metrics.counter("serve_cache_hits_total").inc(bucket=bucket.key)
            return entry
        self.misses += 1
        _metrics.counter("serve_cache_misses_total").inc(bucket=bucket.key)
        from repro.pipeline import pick_config
        config = pick_config(csr, self.dim, decider=self.decider,
                             op=self.op, heads=self.heads)
        entry = BucketPack(bucket, config, PackGeom.from_bucket(bucket,
                                                                config))
        self._entries[bucket] = entry
        if len(self._entries) > self.capacity:
            evicted, _ = self._entries.popitem(last=False)
            self.evictions += 1
            _metrics.counter("serve_cache_evictions_total").inc(
                bucket=evicted.key)
        return entry

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
