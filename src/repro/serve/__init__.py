"""GNN inference serving tier (docs/SERVING.md).

Request path: seed node ids → seeded fanout-capped k-hop sampling →
induced-subgraph extraction with local relabeling → shape-bucket PCSR
pack (padded to the bucket ceiling) → fused GCN/GIN/GAT forward —
with dynamic request batching into pre-compiled shape buckets and a
bucket-keyed steering-pack cache amortizing the decider/cost-model
config pick.  The graph-side counterpart of ``repro.launch.serve``'s
prefill+decode LM path.
"""
from .batcher import (RequestBatcher, SampledRequest, SubgraphRequest,
                      synthetic_stream)
from .bucket import (BucketPolicy, PackGeom, ShapeBucket, pack_subgraph,
                     steering_arrays)
from .cache import BucketPack, SteeringPackCache
from .forward import bucket_forward, reference_forward
from .service import GNNService, RequestResult, replay

__all__ = [
    "ShapeBucket", "BucketPolicy", "PackGeom", "pack_subgraph",
    "steering_arrays", "BucketPack", "SteeringPackCache",
    "SubgraphRequest", "SampledRequest", "RequestBatcher",
    "synthetic_stream", "bucket_forward", "reference_forward",
    "GNNService", "RequestResult", "replay",
]
