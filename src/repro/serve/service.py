"""GNNService — the request path, end to end.

``submit`` runs the sampling stage (seeded k-hop fanout-capped expansion
+ induced-subgraph extraction with local relabeling, span
``serve.sample``) and queues the result; ``tick`` drains the batcher,
and for each batch: coalesces the member subgraphs into one
block-diagonal union (requests can't interact — their outputs are
exactly the isolated per-request outputs), picks the shape bucket,
fetches the bucket's steering pack from the cache (span ``serve.pack``,
config pick amortized), pads features to the bucket ceiling, and runs
the jitted bucket forward (span ``serve.forward``).  Per-request outputs
are the forward's rows at each request's seed positions.

Everything is deterministic given the request stream: sampling is
seeded per request, batch composition is a pure function of queue
order, and the padded layouts are fixed per bucket — same stream, same
outputs, bit for bit.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import CSRMatrix
from repro.data.graphs import extract_subgraph, sample_khop
from repro.obs import metrics as _metrics
from repro.obs import span

from .batcher import RequestBatcher, SampledRequest, SubgraphRequest
from .bucket import BucketPolicy, pack_subgraph, steering_arrays
from .cache import SteeringPackCache
from .forward import bucket_forward


@dataclass
class RequestResult:
    rid: str
    outputs: np.ndarray        # (n_seeds, out_dim) rows for req.seeds
    bucket_key: str
    latency_s: float
    config: object = None      # the SpMMConfig the batch was served under
    sampled: SampledRequest | None = None   # kept when keep_subgraphs


def _model_dims(model: str, params) -> int:
    """Widest layer width — the config pick's embedding-dim argument."""
    if model == "gat":
        return max(int(l["wv"].shape[1]) for l in params)
    if model == "gin":
        return max(int(l["w1"].shape[1]) for l in params)
    return max(int(l["w"].shape[1]) for l in params)


def _union_csr(members) -> CSRMatrix:
    """Block-diagonal union of the members' local-id subgraphs."""
    n_tot = sum(sr.n for sr in members)
    indptr = [np.zeros(1, np.int64)]
    indices, data = [], []
    n_off = e_off = 0
    for sr in members:
        indptr.append(sr.sub.indptr[1:] + e_off)
        indices.append(sr.sub.indices + n_off)
        data.append(sr.sub.data)
        n_off += sr.n
        e_off += int(sr.sub.indices.size)
    return CSRMatrix(np.concatenate(indptr), np.concatenate(indices),
                     np.concatenate(data), n_tot, n_tot)


class GNNService:
    """Serve a GNN over one base graph.

    ``csr`` is the propagation matrix to sample from (pre-normalize it
    for GCN — per-subgraph renormalization is deliberately NOT applied:
    edge weights travel with the extracted edges), ``features`` the
    ``(n_nodes, f)`` node features, ``params`` the model parameters.
    ``keep_subgraphs=True`` retains each request's sampled subgraph on
    its result so callers (the ``--check`` driver path, the exactness
    tests) can re-run the full-pipeline reference against it.
    """

    def __init__(self, csr: CSRMatrix, features, params, *,
                 model: str = "gcn", backend: str = "engine",
                 interpret: bool = True,
                 policy: BucketPolicy | None = None,
                 cache_capacity: int = 8, decider=None,
                 max_batch: int = 32, keep_subgraphs: bool = False):
        if model not in ("gcn", "gin", "gat"):
            raise ValueError(f"unknown model {model!r}")
        self.csr = csr
        self.features = np.asarray(features, np.float32)
        self.params = params
        self.model = model
        self.backend = backend
        self.interpret = interpret
        self.policy = policy or BucketPolicy.default()
        self.keep_subgraphs = keep_subgraphs
        self.cache = SteeringPackCache(
            dim=_model_dims(model, params), capacity=cache_capacity,
            op="gat" if model == "gat" else "spmm", decider=decider)
        big = self.policy.largest
        self.batcher = RequestBatcher(n_max=big.n_ceil, e_max=big.e_ceil,
                                      max_batch=max_batch)
        self.batch_log: list = []       # (bucket_key, (rid, ...)) per batch
        self.requests_served = 0
        self._geoms: set = set()        # distinct compiled-forward keys

    # ------------------------------------------------------------ intake
    def submit(self, req: SubgraphRequest) -> SampledRequest:
        """Sample + extract the request's subgraph and queue it."""
        with span("serve.sample", rid=req.rid, seeds=len(req.seeds)):
            t0 = time.perf_counter()
            nodes = sample_khop(self.csr, req.seeds, req.fanouts,
                                seed=req.sample_seed)
            sub = extract_subgraph(self.csr, nodes)
            seed_local = np.searchsorted(
                nodes, np.unique(np.asarray(req.seeds, np.int64)))
            sr = SampledRequest(req, nodes, sub, seed_local,
                                t_submit=t0)
            _metrics.counter("serve_requests_total").inc(model=self.model)
        self.batcher.add(sr)
        return sr

    # ------------------------------------------------------------- serve
    def tick(self) -> list:
        """Drain the queue and serve every pending batch."""
        results = []
        for members in self.batcher.drain():
            results.extend(self._run_batch(members))
        return results

    def _run_batch(self, members) -> list:
        n_tot = sum(sr.n for sr in members)
        union = _union_csr(members)
        e_tot = int(union.indices.size)
        bucket = self.policy.pick(n_tot, e_tot)
        with span("serve.batch", bucket=bucket.key, requests=len(members),
                  nodes=n_tot, edges=e_tot):
            with span("serve.pack", bucket=bucket.key):
                t0 = time.perf_counter()
                pack = self.cache.get(bucket, union)
                steer = steering_arrays(pack_subgraph(union, pack.geom))
                _metrics.histogram("serve_pack_seconds").observe(
                    time.perf_counter() - t0, bucket=bucket.key)
            self._geoms.add((pack.geom, self.model, self.backend))
            X = np.zeros((pack.geom.n_rows, self.features.shape[1]),
                         np.float32)
            X[:n_tot] = self.features[
                np.concatenate([sr.nodes for sr in members])]
            with span("serve.forward", bucket=bucket.key):
                out = bucket_forward(steer, jnp.asarray(X), self.params,
                                     geom=pack.geom, model=self.model,
                                     backend=self.backend,
                                     interpret=self.interpret)
                out = np.asarray(out)
        now = time.perf_counter()
        results, off = [], 0
        for sr in members:
            rows = off + sr.seed_local
            results.append(RequestResult(
                rid=sr.req.rid, outputs=out[rows], bucket_key=bucket.key,
                latency_s=now - sr.t_submit, config=pack.config,
                sampled=sr if self.keep_subgraphs else None))
            off += sr.n
        self.batch_log.append((bucket.key,
                               tuple(sr.req.rid for sr in members)))
        self.requests_served += len(members)
        return results

    @property
    def compiled_buckets(self) -> int:
        """Distinct (geometry, model, backend) forwards this service has
        dispatched — an upper bound on the compilations it caused (exact
        in a fresh process; the obs ``serve_recompiles_total`` counter is
        the trace-time ground truth)."""
        return len(self._geoms)


def replay(service: GNNService, stream, *, tick_every: int = 8) -> list:
    """Drive a request stream through the service deterministically:
    submit in arrival order, tick whenever ``tick_every`` requests are
    pending, drain at the end.  Returns results in completion order."""
    results = []
    for req in stream:
        service.submit(req)
        if len(service.batcher) >= tick_every:
            results.extend(service.tick())
    results.extend(service.tick())
    return results
