"""Dynamic request batching — coalesce sampled subgraphs per tick.

Requests are queued in arrival order and drained into batches whose
block-diagonal union stays inside the policy's largest bucket (greedy
FIFO: a batch closes when the next request would overflow the node or
edge ceiling, or the per-batch request cap).  Batch composition is a
pure function of the queue contents — no wall-clock dependence — so a
seeded stream replays deterministically, which is what the soak test
asserts.

``synthetic_stream`` generates the seeded bursty workload (geometric
burst sizes, exponential inter-burst gaps, mixed fanouts/seed counts)
used by the soak test, the CI smoke, and ``bench_serve``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class SubgraphRequest:
    """One inference request: expand ``seeds`` by ``fanouts`` and return
    the served model's outputs on the seed nodes."""

    rid: str
    seeds: tuple
    fanouts: tuple
    sample_seed: int = 0
    arrival_s: float = 0.0


@dataclass
class SampledRequest:
    """A request after the sampling stage: its global node set, the
    relabeled induced subgraph, and where its seeds sit locally."""

    req: SubgraphRequest
    nodes: np.ndarray          # sorted unique global node ids
    sub: "object"              # CSRMatrix, local ids
    seed_local: np.ndarray     # positions of req.seeds within nodes
    t_submit: float = 0.0

    @property
    def n(self) -> int:
        return int(self.nodes.size)

    @property
    def e(self) -> int:
        return int(self.sub.indices.size)


@dataclass
class RequestBatcher:
    """FIFO queue + greedy coalescing under (n_max, e_max) ceilings."""

    n_max: int
    e_max: int
    max_batch: int = 32
    _queue: list = field(default_factory=list)

    def add(self, sr: SampledRequest):
        if sr.n > self.n_max or sr.e > self.e_max:
            raise ValueError(
                f"request {sr.req.rid} ({sr.n} nodes, {sr.e} edges) "
                f"exceeds the largest bucket ({self.n_max}, {self.e_max})")
        self._queue.append(sr)

    def __len__(self) -> int:
        return len(self._queue)

    def drain(self) -> list:
        """Split the queue into batches (lists of SampledRequest), FIFO,
        each fitting the ceilings.  Empties the queue."""
        batches, cur, n_tot, e_tot = [], [], 0, 0
        for sr in self._queue:
            if cur and (n_tot + sr.n > self.n_max
                        or e_tot + sr.e > self.e_max
                        or len(cur) >= self.max_batch):
                batches.append(cur)
                cur, n_tot, e_tot = [], 0, 0
            cur.append(sr)
            n_tot += sr.n
            e_tot += sr.e
        if cur:
            batches.append(cur)
        self._queue = []
        return batches


def synthetic_stream(n_requests: int, n_nodes: int, *, seed: int = 0,
                     max_seeds: int = 4,
                     fanout_choices=((4, 2), (8, 4), (2, 2), (6,)),
                     burst_mean: float = 3.0,
                     gap_mean_s: float = 0.01) -> list:
    """Seeded bursty request stream against an ``n_nodes`` graph.

    Bursts of geometric size arrive after exponential gaps; each request
    draws 1..max_seeds random seed nodes, a random fanout profile, and
    its own derived sampling seed.  Fully deterministic in ``seed``.
    """
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    i = 0
    while i < n_requests:
        t += float(rng.exponential(gap_mean_s))
        burst = min(int(rng.geometric(1.0 / burst_mean)), n_requests - i)
        for _ in range(burst):
            k = int(rng.integers(1, max_seeds + 1))
            seeds = tuple(int(s) for s in rng.integers(0, n_nodes, k))
            fanouts = fanout_choices[int(rng.integers(len(fanout_choices)))]
            out.append(SubgraphRequest(
                rid=f"r{i}", seeds=seeds, fanouts=tuple(fanouts),
                sample_seed=int(rng.integers(1 << 31)), arrival_s=t))
            i += 1
    return out
