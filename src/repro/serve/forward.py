"""Bucketed GNN forwards — one compilation per (bucket, model, backend).

The hot path is a module-level ``jax.jit`` function whose *traced*
operands are the steering arrays, features, and parameters, and whose
*static* operand is the bucket's ``PackGeom``.  Because every batch in a
bucket produces steering arrays of identical shapes (``pack_subgraph``),
the compiled program is reused for the life of the process — the
closure-style builders in ``core.engine`` (which bake the arrays in as
constants and therefore recompile per graph) must never appear here.

``serve_recompiles_total`` increments *at trace time only* (the Python
body of a jitted function runs once per compilation), making it a true
recompile counter: the soak test asserts it stays flat after one
warm-up pass per bucket.

Layer semantics are literally ``models.gnn.gcn_forward`` /
``gin_forward`` / ``gat_forward`` — the serve path only swaps in a
steering-array-parameterized aggregation closure, so serving cannot
drift from the training forward.  Exactness: with integer-valued
features/weights the GCN/GIN serve output is bit-equal to the
full-pipeline reference (padding slots add exact zeros; integer sums are
order-free); GAT's softmax normalizer is summed in layout order, so the
serve output matches the reference to float tolerance, not bits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (_engine, _engine_sddmm, _slot_rows,
                               apply_epilogue, attend_scores, engine_spmm,
                               engine_spmm_fused, make_gat_message_fn)
from repro.core.pcsr import build_pcsr
from repro.models.gnn import gat_forward, gcn_forward, gin_forward
from repro.obs import metrics as _metrics

from .bucket import PackGeom


def _bucket_spmm(steer, geom: PackGeom, backend: str, interpret: bool):
    """``spmm(B)`` + ``.fused(...)`` closures over *traced* steering
    arrays with static bucket geometry — the serving analogue of
    ``ParamSpMMOperator``'s fusion surface."""
    cfg = geom.config

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import _call

        def call(B, scale=None, bias=None, activation="none", residual=None):
            return _call(steer["colidx"], steer["lrow"], steer["trow"],
                         steer["init"], steer["fini"], steer["vals"], B,
                         None, None, scale, bias, residual,
                         n_blocks=geom.n_blocks, R=cfg.R, V=cfg.V,
                         K=geom.K, dblk=cfg.dblk, n_rows=geom.n_rows,
                         dim=B.shape[1], activation=activation,
                         interpret=interpret)

        def spmm(B):
            return call(B)

        def fused(B, scale=None, bias=None, activation="none",
                  residual=None):
            return call(B, scale, bias, activation, residual)
    else:
        def spmm(B):
            return _engine(steer["colidx"], steer["lrow"], steer["trow"],
                           steer["vals"], B, V=cfg.V, R=cfg.R, K=geom.K,
                           n_blocks=geom.n_blocks, n_rows=geom.n_rows)

        def fused(B, scale=None, bias=None, activation="none",
                  residual=None):
            return apply_epilogue(spmm(B), scale, bias, activation,
                                  residual=residual)

    spmm.fused = fused
    return spmm


def _bucket_gat_msg(steer, geom: PackGeom, backend: str, interpret: bool,
                    slope: float = 0.2):
    """Single-head fused GAT message over traced steering arrays —
    SDDMM → LeakyReLU → edge softmax → SpMM, same two-kernel structure
    as ``make_gat_message_fn`` but shape-stable across requests."""
    cfg = geom.config
    V, R, K, nb = cfg.V, cfg.R, geom.K, geom.n_blocks

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import _call
        from repro.kernels.sddmm.ops import _stats_call

        def msg(Q, K_mat, Vf):
            scale = float(1.0 / np.sqrt(Q.shape[-1]))
            logits, rowmax, rowsum = _stats_call(
                steer["colidx"], steer["lrow"], steer["trow"],
                steer["init"], steer["vals"], Q[None], K_mat[None],
                H=1, n_blocks=nb, R=R, W=cfg.W, V=V, K=K, dblk=cfg.dblk,
                scale=scale, slope=slope, interpret=interpret)
            logits = logits.reshape(geom.num_chunks, V, K)
            return _call(steer["colidx"], steer["lrow"], steer["trow"],
                         steer["init"], steer["fini"], logits, Vf,
                         rowmax, rowsum, n_blocks=nb, R=R, V=V, K=K,
                         dblk=cfg.dblk, n_rows=geom.n_rows,
                         dim=Vf.shape[1], interpret=interpret)
    else:
        def msg(Q, K_mat, Vf):
            mask = steer["vals"] != 0
            rows = _slot_rows(steer["lrow"], steer["trow"], V=V, R=R, K=K)
            scores = _engine_sddmm(steer["colidx"], steer["lrow"],
                                   steer["trow"], steer["vals"], Q, K_mat,
                                   V=V, R=R, K=K)
            alpha = attend_scores(scores, mask, rows, nb * R,
                                  dim_k=Q.shape[1], slope=slope)
            return _engine(steer["colidx"], steer["lrow"], steer["trow"],
                           alpha, Vf, V=V, R=R, K=K, n_blocks=nb,
                           n_rows=geom.n_rows)

    return msg


@functools.partial(jax.jit,
                   static_argnames=("geom", "model", "backend", "interpret"))
def bucket_forward(steer, X, params, *, geom: PackGeom, model: str,
                   backend: str = "engine", interpret: bool = True):
    """Full GNN forward on one bucket-padded batch.

    Traced: ``steer`` (steering dict from ``steering_arrays``), ``X``
    (``(geom.n_rows, f)`` padded features), ``params`` (the model's
    parameter pytree).  Static: the bucket geometry + model/backend —
    the complete jit cache key.  Rows past the real batch are padding
    (zero features, zero edges) and are sliced off by the caller.
    """
    _metrics.counter("serve_recompiles_total").inc(
        model=model, backend=backend,
        bucket=f"r{geom.n_rows}c{geom.num_chunks}")
    if model == "gcn":
        return gcn_forward(params, X, _bucket_spmm(steer, geom, backend,
                                                   interpret))
    if model == "gin":
        return gin_forward(params, X, _bucket_spmm(steer, geom, backend,
                                                   interpret))
    if model == "gat":
        return gat_forward(params, X, _bucket_gat_msg(steer, geom, backend,
                                                      interpret))
    raise ValueError(f"unknown model {model!r}")


def reference_forward(csr, X, params, *, model: str, config,
                      backend: str = "engine", interpret: bool = True):
    """The full-pipeline forward on an *unpadded* subgraph — the serving
    exactness oracle.  Builds a fresh PCSR under ``config`` (pass the
    serving pack's config: GAT's softmax is layout-sensitive) and runs
    the same ``models.gnn`` forward through the standard closure
    builders."""
    p = build_pcsr(csr.indptr, csr.indices, csr.data, csr.n_rows,
                   csr.n_cols, config)
    X = jnp.asarray(X)
    if model == "gat":
        msg = make_gat_message_fn(p, backend=backend, interpret=interpret)
        return gat_forward(params, X, msg)

    if backend == "pallas":
        from repro.kernels.paramspmm.ops import paramspmm

        def spmm(B):
            return paramspmm(p, B, interpret=interpret)

        def fused(B, scale=None, bias=None, activation="none",
                  residual=None):
            return paramspmm(p, B, scale=scale, bias=bias,
                             residual=residual, activation=activation,
                             interpret=interpret)
    else:
        def spmm(B):
            return engine_spmm(p, B)

        def fused(B, scale=None, bias=None, activation="none",
                  residual=None):
            return engine_spmm_fused(p, B, scale=scale, bias=bias,
                                     residual=residual,
                                     activation=activation)

    spmm.fused = fused
    fwd = {"gcn": gcn_forward, "gin": gin_forward}[model]
    return fwd(params, X, spmm)
