"""Shape buckets — the serving tier's compilation-stability contract.

Every inference request carries a different sampled subgraph, and a
jitted forward recompiles on any shape change.  Serving therefore rounds
each request batch up to one of a small, fixed ladder of
``(node_ceiling, edge_ceiling)`` buckets; a bucket maps to one
``PackGeom`` — a *fully static* PCSR geometry (rows, blocks, chunk count,
chunk capacity, ⟨W,F,V,S,B⟩ config) — so every batch packed into the
bucket produces steering arrays of bit-identical shapes and shares ONE
compiled kernel for the life of the process.

The bucket geometry leaves deliberate headroom:

* ``n_rows = round_up(n_ceil, R) + R`` — one extra, always-empty row
  block, so ``pad_pcsr`` always has a legal target for its filler
  chunks even when a batch lands exactly on the node ceiling;
* ``num_chunks = n_blocks + ceil(e_ceil / K)`` — provably enough for
  any edge distribution at or under the ceiling (each nonempty block
  wastes at most one partial chunk: ``Σ_b ceil(c_b/K) ≤ n_nonempty +
  ceil(e/K)``, and empty blocks take exactly one coverage chunk each).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.pcsr import (PCSR, SUBLANES, SpMMConfig, _round_up,
                             build_pcsr, pad_pcsr)
from repro.core.sparse import CSRMatrix


@dataclass(frozen=True)
class ShapeBucket:
    """One rung of the padding ladder: requests with ``n ≤ n_ceil`` nodes
    and ``e ≤ e_ceil`` edges are padded up to exactly this shape."""

    n_ceil: int
    e_ceil: int

    @property
    def key(self) -> str:
        return f"n{self.n_ceil}e{self.e_ceil}"

    def fits(self, n: int, e: int) -> bool:
        return n <= self.n_ceil and e <= self.e_ceil


class BucketPolicy:
    """An ordered ladder of shape buckets + the pick rule.

    ``pick`` returns the *smallest* bucket that fits (least padding —
    the latency-vs-padding tradeoff is the ladder's spacing: a doubling
    ladder wastes ≤ 2× padded work per request while keeping the number
    of compiled programs logarithmic in the request-size range).
    """

    def __init__(self, buckets):
        if not buckets:
            raise ValueError("empty bucket ladder")
        self.buckets = sorted(buckets, key=lambda b: (b.n_ceil, b.e_ceil))

    @staticmethod
    def default(n_min: int = 128, e_min: int = 512,
                n_max: int = 4096, e_max: int = 65536) -> "BucketPolicy":
        """Doubling ladder from (n_min, e_min) to (n_max, e_max)."""
        out = []
        n, e = n_min, e_min
        while True:
            out.append(ShapeBucket(n, e))
            if n >= n_max and e >= e_max:
                break
            n, e = min(2 * n, n_max), min(2 * e, e_max)
        return BucketPolicy(out)

    @property
    def largest(self) -> ShapeBucket:
        return self.buckets[-1]

    def pick(self, n: int, e: int) -> ShapeBucket:
        for b in self.buckets:
            if b.fits(n, e):
                return b
        raise ValueError(
            f"request batch ({n} nodes, {e} edges) exceeds the largest "
            f"bucket {self.largest.key}")


@dataclass(frozen=True)
class PackGeom:
    """The static PCSR geometry of one bucket under one config — the
    (hashable) jit cache key of the bucket's compiled forward.  Every
    subgraph packed through ``pack_subgraph`` with the same ``PackGeom``
    yields steering arrays of identical shapes."""

    config: SpMMConfig
    n_rows: int
    n_blocks: int
    num_chunks: int
    K: int

    @staticmethod
    def from_bucket(bucket: ShapeBucket, config: SpMMConfig) -> "PackGeom":
        R = config.R
        n_rows = _round_up(bucket.n_ceil, R) + R   # +R: always-empty block
        n_panels = n_rows // config.V
        n_blocks = n_panels // config.W
        mean = -(-bucket.e_ceil // max(1, n_blocks - 1))
        K = max(SUBLANES, _round_up(mean, SUBLANES))
        num_chunks = n_blocks + -(-bucket.e_ceil // K)
        return PackGeom(config, n_rows, n_blocks, num_chunks, K)

    @property
    def num_slots(self) -> int:
        return self.num_chunks * self.K


def pack_subgraph(csr: CSRMatrix, geom: PackGeom) -> PCSR:
    """Pack a (relabeled) subgraph into the bucket's fixed geometry:
    build at the bucket's pinned chunk capacity, then pad rows and
    chunks to the ceiling.  The result has zero empty blocks (covered
    steering == uncovered), so every backend sees stable shapes."""
    if csr.n_rows > geom.n_rows - geom.config.R:
        raise ValueError(
            f"subgraph ({csr.n_rows} rows) exceeds bucket rows "
            f"({geom.n_rows} incl. the reserved empty block)")
    p = build_pcsr(csr.indptr, csr.indices, csr.data,
                   csr.n_rows, csr.n_cols, geom.config, capacity=geom.K)
    return pad_pcsr(p, n_rows=geom.n_rows, n_cols=geom.n_rows,
                    num_chunks=geom.num_chunks)


def steering_arrays(padded: PCSR):
    """Device-ready steering dict (colidx/lrow/trow/init/fini/vals) of a
    bucket-padded PCSR — the pytree operand of ``bucket_forward``."""
    import jax.numpy as jnp
    st = padded.steering()
    return {k: jnp.asarray(v) for k, v in st.items()}
