"""GAT (attention GNN): dot-product edge attention via the PCSR
SDDMM→softmax→SpMM pair; layer count/dims match the GCN setup."""
GAT = {"model": "gat", "n_layers": 3, "in_dim": 16, "out_dim": 16,
       "hidden": 64}
CONFIG = GAT
REDUCED = {**GAT, "hidden": 32}
