"""GAT (attention GNN): dot-product edge attention via the PCSR
SDDMM→softmax→SpMM pair; layer count/dims match the GCN setup.

``heads`` batches the attention head dimension through the kernels — the
Pallas backend runs all heads in one head-tiled kernel call per operator
(one compilation), hidden layers concatenate heads, the output layer
averages them (``hidden`` must divide by ``heads``)."""
GAT = {"model": "gat", "n_layers": 3, "in_dim": 16, "out_dim": 16,
       "hidden": 64, "heads": 1}
CONFIG = GAT
REDUCED = {**GAT, "hidden": 32}
# multi-head variant: 4 heads of 16 channels concatenated per hidden layer
GAT_MH = {**GAT, "heads": 4}
