"""llava-next-mistral-7b [vlm]: 32L d4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — mistral backbone, anyres vision frontend STUB (input_specs
supplies patch embeddings) [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=32000,
    n_patches=1152,                 # anyres: base 576 + 576 tile pool
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=256, n_patches=8)
