"""GIN (paper §6.5): 5 layers, in/out 16, hidden ∈ {32,64,128}."""
GIN = {"model": "gin", "n_layers": 5, "in_dim": 16, "out_dim": 16,
       "hidden": 64}
CONFIG = GIN
REDUCED = {**GIN, "n_layers": 3, "hidden": 32}
