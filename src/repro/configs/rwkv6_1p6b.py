"""rwkv6-1.6b [ssm]: 24L d2048 (attention-free) d_ff=7168 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892].  Linear recurrence →
runs long_500k."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
    d_ff=7168, vocab=65536,
    norm="layernorm", supports_long=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=2, n_kv=2, head_dim=64, d_ff=256,
    vocab=256)
