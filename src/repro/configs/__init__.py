"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

IDs match the assignment (``--arch <id>``)."""
from __future__ import annotations

import importlib

from .base import ArchConfig, ShapeCell, SHAPES, applicable_shapes

_MODULES = {
    "hymba-1.5b": "hymba_1p5b",
    "qwen2-72b": "qwen2_72b",
    "chatglm3-6b": "chatglm3_6b",
    "gemma2-27b": "gemma2_27b",
    "qwen1.5-110b": "qwen15_110b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "whisper-tiny": "whisper_tiny",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "gcn": "gcn",
    "gin": "gin",
    "gat": "gat",
}

ARCH_IDS = [k for k in _MODULES if k not in ("gcn", "gin", "gat")]


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ArchConfig:
    return _mod(arch).REDUCED
