"""GCN (paper §6.5): 5 layers, in/out 16, hidden ∈ {32,64,128}."""
GCN = {"model": "gcn", "n_layers": 5, "in_dim": 16, "out_dim": 16,
       "hidden": 64}
CONFIG = GCN
REDUCED = {**GCN, "n_layers": 3, "hidden": 32}
