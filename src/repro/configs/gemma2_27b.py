"""gemma2-27b [dense]: 46L d4608 32H (GQA kv=16, hd=128) d_ff=36864
vocab=256000 — local/global alternating attention, attn softcap 50,
logit softcap 30, pre+post norms, (1+w) RMSNorm [arXiv:2408.00118]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv=16, head_dim=128,
    d_ff=36864, vocab=256000,
    act="gelu", attn_softcap=50.0, logit_softcap=30.0,
    sliding_window=4096, alternate_local_global=True,
    post_block_norm=True, norm_plus_one=True, embed_scale=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=256, sliding_window=8)
