"""whisper-tiny [audio]: 4L enc + 4L dec, d384 6H d_ff=1536 vocab=51865 —
enc-dec, conv frontend STUB (input_specs supplies frame embeddings)
[arXiv:2212.04356]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv=6,
    head_dim=64, d_ff=1536, vocab=51865,
    norm="layernorm", act="gelu",
)

REDUCED = CONFIG.replace(
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=2, n_kv=2,
    head_dim=32, d_ff=128, vocab=256)
