"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5, hd=64) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676].
Sub-quadratic (SWA + SSM) → runs long_500k."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, head_dim=64,
    d_ff=5504, vocab=32001,
    ssm_state=16, ssm_expand=2,
    sliding_window=1024, n_global_layers=3,
    supports_long=True,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=256, sliding_window=8, n_global_layers=1, ssm_state=4)
