"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8, hd=64) expert
d_ff=512 vocab=49155, MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8, expert_d_ff=512,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=64,
    vocab=256, n_experts=4, top_k=2, expert_d_ff=64)
