"""Unified architecture config covering all assigned families."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads

    # attention details
    qkv_bias: bool = False
    rope_fraction: float = 1.0   # chatglm3 "2d RoPE" = rotary on half dims
    rope_base: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_plus_one: bool = False  # gemma-style (1+w) scale
    act: str = "silu"            # silu | gelu  (gated MLP)
    attn_softcap: float = 0.0    # gemma2: 50.0
    logit_softcap: float = 0.0   # gemma2: 30.0
    sliding_window: int = 0      # gemma2 local layers / hymba SWA
    alternate_local_global: bool = False     # gemma2
    post_block_norm: bool = False            # gemma2 pre+post norms
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma-style sqrt(d) embedding scaling

    # MoE (granite)
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0

    # SSM (hymba mamba branch / rwkv)
    ssm_state: int = 0
    ssm_expand: int = 2
    n_global_layers: int = 0     # hymba: layers with full attention

    # enc-dec (whisper)
    n_enc_layers: int = 0

    # vlm (llava)
    n_patches: int = 0           # patch-embedding prefix length per sequence

    # which shape cells apply (spec: skip long_500k for quadratic attns,
    # skip decode for encoder-only — none here are encoder-only)
    supports_long: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding to 128 so the embedding /
        unembedding shard vocab-parallel (odd vocabs — granite 49155,
        hymba 32001, whisper 51865 — otherwise force a full-vocab f32
        logits all-reduce; §Perf iteration 5).  Logits beyond ``vocab``
        are masked at the loss/decode boundary."""
        return -(-self.vocab // 128) * 128

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return out
