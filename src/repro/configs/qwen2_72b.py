"""qwen2-72b [dense]: 80L d8192 64H (GQA kv=8) d_ff=29568 vocab=152064 —
GQA, QKV bias [arXiv:2407.10671]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
    d_ff=29568, vocab=152064,
    qkv_bias=True, tie_embeddings=False,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=256)
