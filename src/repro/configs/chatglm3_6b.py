"""chatglm3-6b [dense]: 28L d4096 32H (GQA kv=2) d_ff=13696 vocab=65024 —
2d (partial) RoPE, GQA, QKV bias [arXiv:2406.12793]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, head_dim=128,
    d_ff=13696, vocab=65024,
    qkv_bias=True, rope_fraction=0.5,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=256)
