"""PCSR-driven SDDMM — the attention half of the GAT operator pair.

SDDMM (sampled dense-dense matrix multiplication) computes
``E = (A ≠ 0) ⊙ (Q·Kᵀ)``: one dot product per stored nonzero of ``A``.
Together with SpMM it forms the two-kernel core of attention GNNs
(HGL-proto's ``GSDDMMFunction`` + ``GSPMMFunction`` pairing): SDDMM
produces per-edge scores, a row-wise softmax normalizes them, and SpMM
aggregates neighbor features under the resulting edge weights.

Design mapping (paper ⟨W,F,V,S⟩ → SDDMM traversal)
---------------------------------------------------
The kernel consumes the *same* packed PCSR arrays as ParamSpMM — one
⟨W,F,V,S⟩ configuration serves both operators, so the decider/autotune
machinery transfers unchanged:

* **V** — a slot holds a V×1 column-vector of edges: one gathered ``K``
  row (the paper's one irregular load) feeds V query rows' dot products,
  exactly as it feeds V output rows in SpMM.
* **F** — thread coarsening becomes the reduction tile: each grid step
  reduces ``Dblk = F·128`` lanes of ``Q[row]·K[col]`` into the slot's
  partial score; J = ceil(d/Dblk) steps complete the dot product.
* **W** — ``W`` panels form the ``R = V·W``-row block that SpMM
  accumulates; SDDMM reuses the block/panel addressing (``trow``/``lrow``)
  to locate the query row of every slot.
* **S** — split chunks need no atomics here at all: SDDMM's output is
  per-slot (``(C, V, K)``), so splitting a heavy block across chunks is
  pure parallelism — each chunk owns its slots.

Slots padded during PCSR packing are masked post-kernel with
``vals != 0`` (matching the dense oracle's ``A ≠ 0`` sampling), so the
edge-score tensor is exact whatever the padding ratio.

Entry points
------------
``sddmm``                — raw masked scores (C, V, K); multi-head aware.
``sddmm_softmax_stats``  — fused GAT front half, stats form: one kernel
  pass → (logits, rowmax, rowsum) with the per-row max/normalizer
  accumulated *inside* the kernel epilogue (flash-attention-style online
  rescale in the VMEM-resident stats block) so split chunks of a row
  combine exactly.  Feeds the ParamSpMM softmax prologue directly: the
  GAT forward is two kernels, zero interstitial elementwise passes.
``sddmm_softmax``        — materialized-α reference form (stats pass +
  one elementwise normalize).
All accept ``(H, n, d)`` stacks and run every head through ONE kernel
call over head-tiled steering arrays (``PCSR.steering``) — one
compilation for the whole head batch.
"""
from .ops import (normalize_from_stats, sddmm, sddmm_softmax,
                  sddmm_softmax_stats)
from .ref import sddmm_dense_ref, sddmm_slots_ref
