"""Oracles for the SDDMM kernel / engine.

``sddmm_dense_ref`` is the definitional reference ``(A≠0) ⊙ (Q·Kᵀ)``;
``sddmm_slots_ref`` replays the PCSR slot accounting in plain numpy so the
packed ``(C, V, K)`` score tensor can be checked slot-for-slot.
"""
from __future__ import annotations

import numpy as np


def sddmm_dense_ref(A_dense, Q, K):
    """E[i,j] = Q[i]·K[j] where A[i,j] ≠ 0, else 0."""
    A = np.asarray(A_dense)
    scores = np.asarray(Q, np.float32) @ np.asarray(K, np.float32).T
    return np.where(A != 0, scores, 0.0).astype(np.float32)


def sddmm_slots_ref(pcsr, Q, K):
    """Per-slot scores (C, V, K) by direct slot traversal (numpy loop)."""
    Q = np.asarray(Q, np.float32)
    K_mat = np.asarray(K, np.float32)
    cfg = pcsr.config
    V, R, Ks = cfg.V, cfg.R, pcsr.K
    out = np.zeros((pcsr.num_chunks, V, Ks), np.float32)
    for c in range(pcsr.num_chunks):
        for k in range(Ks):
            col = pcsr.colidx[c * Ks + k]
            base = pcsr.trow[c] * R + pcsr.lrow[c * Ks + k] * V
            for v in range(V):
                row = base + v
                if pcsr.vals[c, v, k] != 0 and row < pcsr.n_rows:
                    out[c, v, k] = Q[row] @ K_mat[col]
    return out
