"""SDDMM Pallas TPU kernel — PCSR chunk traversal, dot-product reduction.

Mirror image of ``kernels/paramspmm/kernel.py``: the same scalar-prefetched
``colidx``/``lrow``/``trow`` arrays steer the grid, but the data flow is
reversed — instead of scattering ``val · B[col]`` into an output block, each
step *reduces* ``Q[row] · K[col]`` over a ``Dblk``-lane tile into the slot's
score.  Grid ``(C, K, J)`` keeps the ``(1, V, K)`` output block resident in
VMEM across all ``K·J`` steps of a chunk (consecutive revisits, the same
trick the SpMM kernel plays with ``trow``), so partial dot products
accumulate race-free in the sequential grid.

Block selection per step ``(c, k, j)``:
  Q block ``(V, Dblk)`` at panel ``trow[c]·W + lrow[c·K+k]`` — the paper's
  coalesced dense-row access; K block ``(1, Dblk)`` at ``colidx[c·K+k]`` —
  the one irregular gather, driven by scalar prefetch exactly as in SpMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(colidx_ref, lrow_ref, trow_ref,             # scalar prefetch
            q_ref, k_ref,                               # VMEM inputs
            out_ref,                                    # VMEM output
            *, K: int):
    k = pl.program_id(1)
    j = pl.program_id(2)

    # first step of this chunk's pass → zero the (1, V, K) score block
    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qv = q_ref[...]                          # (V, Dblk) query panel
    kv = k_ref[0, :]                         # (Dblk,) gathered key row
    partial = jnp.sum(qv * kv[None, :], axis=1)          # (V,)
    out_ref[0, :, k] = out_ref[0, :, k] + partial


def sddmm_kernel(colidx, lrow, trow, Q_padded, K_padded, *,
                 W: int, V: int, K: int, dblk: int,
                 interpret: bool = True):
    """Raw per-slot scores on pre-padded operands.

    Q_padded: (n_blocks·R, J·dblk); K_padded: (n_k, J·dblk).
    Returns scores (C, V, K) — unmasked (padding slots score garbage;
    the ops.py wrapper applies the ``vals != 0`` sampling mask).
    """
    C = trow.shape[0]
    dim_pad = Q_padded.shape[1]
    assert dim_pad % dblk == 0
    assert Q_padded.shape[0] % V == 0
    J = dim_pad // dblk
    grid = (C, K, J)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            # query panel: V rows addressed by block·W + local panel index
            pl.BlockSpec((V, dblk),
                         lambda c, k, j, ci, lr, tr: (tr[c] * W + lr[c * K + k], j)),
            # the gather: K row chosen by the scalar-prefetched colidx
            pl.BlockSpec((1, dblk),
                         lambda c, k, j, ci, lr, tr: (ci[c * K + k], j)),
        ],
        out_specs=pl.BlockSpec((1, V, K),
                               lambda c, k, j, ci, lr, tr: (c, 0, 0)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, V, K), Q_padded.dtype),
        interpret=interpret,
        name=f"sddmm_v{V}_k{K}_w{W}_d{dblk}",
    )
    return fn(colidx, lrow, trow, Q_padded, K_padded)
