"""SDDMM Pallas TPU kernel — PCSR chunk traversal, dot-product reduction.

Mirror image of ``kernels/paramspmm/kernel.py``: the same scalar-prefetched
``colidx``/``lrow``/``trow`` arrays steer the grid, but the data flow is
reversed — instead of scattering ``val · B[col]`` into an output block, each
step *reduces* ``Q[row] · K[col]`` over a ``Dblk``-lane tile into the slot's
score.  Grid ``(C, K, J)`` keeps the ``(1, V, K)`` output block resident in
VMEM across all ``K·J`` steps of a chunk (consecutive revisits, the same
trick the SpMM kernel plays with ``trow``), so partial dot products
accumulate race-free in the sequential grid.

Block selection per step ``(c, k, j)``:
  Q block ``(V, Dblk)`` at panel ``trow[c]·W + lrow[c·K+k]`` — the paper's
  coalesced dense-row access; K block ``(1, Dblk)`` at ``colidx[c·K+k]`` —
  the one irregular gather, driven by scalar prefetch exactly as in SpMM.

``sddmm_softmax_kernel`` extends the same traversal with a fused edge
softmax epilogue: when a slot's dot product completes (its last dim tile),
the score is masked, scaled, LeakyReLU'd, and folded into per-row online
softmax statistics kept in two tile-aligned ``(n_blocks·SUBLANES, LANES)``
outputs addressed by ``trow[c]`` — one full ``(8, 128)`` f32 tile per
block, row stats in sublane 0 / lanes 0..R−1 (R ≤ 32 < 128), so the
block shape is exactly one hardware tile and the layout compiles on real
TPU (a ``(1, R)`` block is neither sublane- nor lane-aligned and only
works in interpret mode).  The same consecutive-revisit trick applies:
with ``S=True`` a row split across chunks accumulates its max/normalizer
exactly while the stats tile is VMEM resident.  ``ops.unpack_stats``
recovers the dense ``(n_blocks, R)`` view for plain-JAX consumers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pcsr import LANES, SUBLANES


def _kernel(colidx_ref, lrow_ref, trow_ref,             # scalar prefetch
            q_ref, k_ref,                               # VMEM inputs
            out_ref,                                    # VMEM output
            *, K: int):
    k = pl.program_id(1)
    j = pl.program_id(2)

    # first step of this chunk's pass → zero the (1, V, K) score block
    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qv = q_ref[...]                          # (V, Dblk) query panel
    kv = k_ref[0, :]                         # (Dblk,) gathered key row
    partial = jnp.sum(qv * kv[None, :], axis=1)          # (V,)
    out_ref[0, :, k] = out_ref[0, :, k] + partial


def _fused_kernel(colidx_ref, lrow_ref, trow_ref, init_ref,   # scalar prefetch
                  vals_ref, q_ref, k_ref,                     # VMEM inputs
                  score_ref, rowmax_ref, rowsum_ref,          # VMEM outputs
                  *, V: int, K: int, J: int, scale: float, slope: float):
    c = pl.program_id(0)
    k = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((k == 0) & (j == 0))
    def _init_scores():
        score_ref[...] = jnp.zeros_like(score_ref)

    # first chunk of this output block → reset its softmax running stats
    @pl.when((k == 0) & (j == 0) & (init_ref[c] == 1))
    def _init_stats():
        rowmax_ref[...] = jnp.full(rowmax_ref.shape, -jnp.inf,
                                   rowmax_ref.dtype)
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)

    qv = q_ref[...]                          # (V, Dblk) query panel
    kv = k_ref[0, :]                         # (Dblk,) gathered key row
    acc = score_ref[0, :, k] + jnp.sum(qv * kv[None, :], axis=1)
    score_ref[0, :, k] = acc

    # Softmax epilogue: once the slot's dot product is complete (last dim
    # tile), scale + LeakyReLU it and fold it into the block's running
    # row-max / row-sum-of-exp (flash-attention-style online rescale).  The
    # stats tile lives at trow[c] (one aligned (8, 128) tile per block, row
    # stats in sublane 0), so split chunks of one block accumulate into the
    # same VMEM-resident tiles across consecutive revisits.
    @pl.when(j == J - 1)
    def _epilogue():
        m = vals_ref[0, :, k] != 0           # (V,) real-edge mask
        x = acc * scale
        x = jnp.where(x >= 0, x, slope * x)  # LeakyReLU
        # masked/padding slots publish −inf: downstream α = exp(logit − m)/Σ
        # (the SpMM prologue, or the backward's recompute) then comes out
        # exactly 0 with no separate mask operand.
        score_ref[0, :, k] = jnp.where(m, x, -jnp.inf)
        xm = jnp.where(m, x, -jnp.inf)       # padding never drives max/sum
        row = lrow_ref[c * K + k] * V
        m_old = rowmax_ref[0, pl.ds(row, V)]
        s_old = rowsum_ref[0, pl.ds(row, V)]
        m_new = jnp.maximum(m_old, xm)
        finite = jnp.isfinite(m_new)         # rows with ≥1 real edge so far
        s_scale = jnp.exp(jnp.where(finite, m_old - m_new, 0.0))
        contrib = jnp.exp(jnp.where(finite, xm - m_new, -jnp.inf))
        rowmax_ref[0, pl.ds(row, V)] = m_new
        rowsum_ref[0, pl.ds(row, V)] = s_old * s_scale + contrib


def sddmm_softmax_kernel(colidx, lrow, trow, init, vals, Q_padded, K_padded, *,
                         n_blocks: int, W: int, V: int, K: int, dblk: int,
                         scale: float, slope: float, interpret: bool = True):
    """Fused SDDMM → edge-softmax statistics, one grid pass.

    Same (C, K, J) traversal as ``sddmm_kernel``, plus an epilogue on each
    slot's final dim tile that applies ``scale`` and LeakyReLU(``slope``),
    masks padding slots to −inf, and maintains per-row online-softmax
    statistics in two extra tile-aligned ``(n_blocks·SUBLANES, LANES)``
    outputs (one (8, 128) tile per block; row r of block b lives at
    ``[b·SUBLANES, r]``).  Returns ``(logits (C, V, K), rowmax, rowsum)``
    where ``rowsum`` is Σ exp(logit − rowmax) over each row's real edges —
    exactly the operands the fused ParamSpMM softmax *prologue* consumes,
    so the GAT forward needs no elementwise pass between the two kernels.
    Rows of never-visited (empty) blocks hold garbage; no real slot maps to
    them, and the prologue's −inf-logit convention keeps even padding slots
    that read garbage stats at exactly α = 0.
    """
    C = trow.shape[0]
    R = V * W
    assert R <= LANES, f"R={R} must fit one stats-tile lane row"
    dim_pad = Q_padded.shape[1]
    assert dim_pad % dblk == 0
    assert Q_padded.shape[0] % V == 0
    J = dim_pad // dblk
    grid = (C, K, J)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            # whole chunk's vals (the edge mask); constant in k, j
            pl.BlockSpec((1, V, K),
                         lambda c, k, j, ci, lr, tr, it: (c, 0, 0)),
            # query panel: V rows addressed by block·W + local panel index
            pl.BlockSpec((V, dblk),
                         lambda c, k, j, ci, lr, tr, it:
                         (tr[c] * W + lr[c * K + k], j)),
            # the gather: K row chosen by the scalar-prefetched colidx
            pl.BlockSpec((1, dblk),
                         lambda c, k, j, ci, lr, tr, it: (ci[c * K + k], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, V, K),
                         lambda c, k, j, ci, lr, tr, it: (c, 0, 0)),
            pl.BlockSpec((SUBLANES, LANES),
                         lambda c, k, j, ci, lr, tr, it: (tr[c], 0)),
            pl.BlockSpec((SUBLANES, LANES),
                         lambda c, k, j, ci, lr, tr, it: (tr[c], 0)),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_fused_kernel, V=V, K=K, J=J,
                          scale=scale, slope=slope),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, V, K), Q_padded.dtype),
            jax.ShapeDtypeStruct((n_blocks * SUBLANES, LANES), Q_padded.dtype),
            jax.ShapeDtypeStruct((n_blocks * SUBLANES, LANES), Q_padded.dtype),
        ],
        interpret=interpret,
        name=f"sddmm_softmax_v{V}_k{K}_w{W}_d{dblk}",
    )
    return fn(colidx, lrow, trow, init, vals, Q_padded, K_padded)


def sddmm_kernel(colidx, lrow, trow, Q_padded, K_padded, *,
                 W: int, V: int, K: int, dblk: int,
                 interpret: bool = True):
    """Raw per-slot scores on pre-padded operands.

    Q_padded: (n_blocks·R, J·dblk); K_padded: (n_k, J·dblk).
    Returns scores (C, V, K) — unmasked (padding slots score garbage;
    the ops.py wrapper applies the ``vals != 0`` sampling mask).
    """
    C = trow.shape[0]
    dim_pad = Q_padded.shape[1]
    assert dim_pad % dblk == 0
    assert Q_padded.shape[0] % V == 0
    J = dim_pad // dblk
    grid = (C, K, J)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            # query panel: V rows addressed by block·W + local panel index
            pl.BlockSpec((V, dblk),
                         lambda c, k, j, ci, lr, tr: (tr[c] * W + lr[c * K + k], j)),
            # the gather: K row chosen by the scalar-prefetched colidx
            pl.BlockSpec((1, dblk),
                         lambda c, k, j, ci, lr, tr: (ci[c * K + k], j)),
        ],
        out_specs=pl.BlockSpec((1, V, K),
                               lambda c, k, j, ci, lr, tr: (c, 0, 0)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, V, K), Q_padded.dtype),
        interpret=interpret,
        name=f"sddmm_v{V}_k{K}_w{W}_d{dblk}",
    )
    return fn(colidx, lrow, trow, Q_padded, K_padded)
