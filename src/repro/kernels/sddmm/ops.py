"""Jit'd wrappers around the SDDMM Pallas kernels.

Two entry points, both multi-head aware (rank-3 ``(H, n, d)`` operands run
every head in ONE kernel call over head-tiled PCSR steering arrays — see
``PCSR.head_tiled`` — so multi-head GAT compiles once):

* ``sddmm(pcsr, Q, K)`` — raw masked edge scores in slot layout;
* ``sddmm_softmax(pcsr, Q, K)`` — the fused GAT attention front half:
  scores → scale → LeakyReLU → edge softmax, with the row-max/normalizer
  accumulated *inside* the kernel epilogue while the score block is VMEM
  resident.  Only a cheap elementwise normalize runs outside the kernel,
  cutting the HBM round-trips the unfused score→segment-softmax path paid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pcsr import PCSR
from repro.kernels.paramspmm.ops import _pad_cols


def _pad_q(Q, n_rows_pad: int, dblk: int):
    """Pad a (..., n, d) query stack to (..., n_rows_pad, J·dblk) rows/lanes."""
    Qp, _ = _pad_cols(Q.reshape(-1, Q.shape[-1]), dblk)
    Qp = Qp.reshape(Q.shape[:-1] + (Qp.shape[-1],))
    pad = [(0, 0)] * (Q.ndim - 2) + [(0, n_rows_pad - Q.shape[-2]), (0, 0)]
    return jnp.pad(Qp, pad)


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "W", "V", "K", "dblk", "interpret"))
def _call(colidx, lrow, trow, vals, Q, K_mat, *, H, n_blocks, R, W, V, K,
          dblk, interpret):
    from .kernel import sddmm_kernel
    Qp = _pad_q(Q, n_blocks * R, dblk).reshape(H * n_blocks * R, -1)
    Kp, _ = _pad_cols(K_mat.reshape(-1, K_mat.shape[-1]), dblk)
    scores = sddmm_kernel(colidx, lrow, trow, Qp, Kp,
                          W=W, V=V, K=K, dblk=dblk, interpret=interpret)
    # sampling mask: padding slots (and explicit zeros) score exactly 0,
    # matching the dense oracle's (A ≠ 0) ⊙ (Q·Kᵀ)
    return jnp.where(vals != 0, scores, 0.0)


def sddmm(pcsr: PCSR, Q, K, *, interpret: bool = True):
    """E = (A≠0) ⊙ (Q·Kᵀ) in PCSR slot layout. Pallas path.

    ``Q``/``K`` of shape (n, d) return (C, V, K) slots; (H, n, d) stacks
    return (H, C, V, K) — all heads in a single head-tiled kernel call.
    """
    Q = jnp.asarray(Q)
    K_mat = jnp.asarray(K)
    single = Q.ndim == 2
    if single:
        Q, K_mat = Q[None], K_mat[None]
    H = Q.shape[0]
    t = pcsr.head_tiled(H)
    cfg = pcsr.config
    scores = _call(t["colidx"], t["lrow"], t["trow"], t["vals"], Q, K_mat,
                   H=H, n_blocks=pcsr.n_blocks, R=cfg.R, W=cfg.W, V=cfg.V,
                   K=pcsr.K, dblk=cfg.dblk, interpret=interpret)
    scores = scores.reshape(H, pcsr.num_chunks, cfg.V, pcsr.K)
    return scores[0] if single else scores


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "W", "V", "K", "dblk", "scale", "slope",
    "interpret"))
def _fused_call(colidx, lrow, trow, init, vals, Q, K_mat, *, H, n_blocks, R,
                W, V, K, dblk, scale, slope, interpret):
    from .kernel import sddmm_softmax_kernel
    Qp = _pad_q(Q, n_blocks * R, dblk).reshape(H * n_blocks * R, -1)
    Kp, _ = _pad_cols(K_mat.reshape(-1, K_mat.shape[-1]), dblk)
    logits, rowmax, rowsum = sddmm_softmax_kernel(
        colidx, lrow, trow, init, vals, Qp, Kp,
        n_blocks=H * n_blocks, W=W, V=V, K=K, dblk=dblk,
        scale=scale, slope=slope, interpret=interpret)
    # cheap elementwise epilogue: slot → row stats gather + normalize.
    # (The expensive parts — row max and Σexp — were computed online in the
    # kernel; this is one exp and one divide per slot, no segment ops.)
    C = trow.shape[0]
    rows = (trow[:, None, None].astype(jnp.int32) * R
            + lrow.reshape(C, 1, K) * V
            + jnp.arange(V, dtype=jnp.int32)[None, :, None])
    mask = vals != 0
    rm = rowmax.reshape(-1)
    rm = jnp.where(jnp.isfinite(rm), rm, 0.0)          # empty rows
    denom = jnp.maximum(rowsum.reshape(-1), 1e-30)
    ex = jnp.where(mask, jnp.exp(logits - rm[rows]), 0.0)
    alpha = ex / denom[rows]
    return alpha, logits


def sddmm_softmax(pcsr: PCSR, Q, K, *, scale: float | None = None,
                  slope: float = 0.2, interpret: bool = True,
                  with_logits: bool = False):
    """Fused GAT attention weights: softmax_row(LeakyReLU(scale·Q·Kᵀ)) on
    A's sparsity pattern, in PCSR slot layout. Pallas path.

    ``scale`` defaults to 1/√d (dot-product attention).  Returns ``alpha``
    — or ``(alpha, logits)`` with ``with_logits=True``, where ``logits`` are
    the masked post-LeakyReLU scores the backward needs for the activation
    derivative.  Shapes follow ``sddmm``: (C, V, K) per (n, d) inputs,
    (H, C, V, K) per (H, n, d).
    """
    Q = jnp.asarray(Q)
    K_mat = jnp.asarray(K)
    single = Q.ndim == 2
    if single:
        Q, K_mat = Q[None], K_mat[None]
    H = Q.shape[0]
    if scale is None:
        scale = float(1.0 / np.sqrt(Q.shape[-1]))
    t = pcsr.head_tiled(H)
    cfg = pcsr.config
    alpha, logits = _fused_call(
        t["colidx"], t["lrow"], t["trow"], t["init"], t["vals"], Q, K_mat,
        H=H, n_blocks=pcsr.n_blocks, R=cfg.R, W=cfg.W, V=cfg.V, K=pcsr.K,
        dblk=cfg.dblk, scale=float(scale), slope=float(slope),
        interpret=interpret)
    shape = (H, pcsr.num_chunks, cfg.V, pcsr.K)
    alpha, logits = alpha.reshape(shape), logits.reshape(shape)
    if single:
        alpha, logits = alpha[0], logits[0]
    return (alpha, logits) if with_logits else alpha
