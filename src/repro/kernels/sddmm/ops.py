"""Jit'd wrapper around the SDDMM Pallas kernel: padding, masking, and the
high-level ``sddmm(pcsr, Q, K)`` entry point."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.pcsr import PCSR
from repro.kernels.paramspmm.ops import _pad_cols


@functools.partial(jax.jit, static_argnames=(
    "n_blocks", "R", "W", "V", "K", "dblk", "interpret"))
def _call(colidx, lrow, trow, vals, Q, K_mat, *, n_blocks, R, W, V, K, dblk,
          interpret):
    from .kernel import sddmm_kernel
    Qp, _ = _pad_cols(Q, dblk)                   # zero rows/lanes add 0
    Qp = jnp.pad(Qp, ((0, n_blocks * R - Qp.shape[0]), (0, 0)))
    Kp, _ = _pad_cols(K_mat, dblk)
    scores = sddmm_kernel(colidx, lrow, trow, Qp, Kp,
                          W=W, V=V, K=K, dblk=dblk, interpret=interpret)
    # sampling mask: padding slots (and explicit zeros) score exactly 0,
    # matching the dense oracle's (A ≠ 0) ⊙ (Q·Kᵀ)
    return jnp.where(vals != 0, scores, 0.0)


def sddmm(pcsr: PCSR, Q, K, *, interpret: bool = True):
    """E = (A≠0) ⊙ (Q·Kᵀ) in PCSR slot layout (C, V, K). Pallas path."""
    arrs = pcsr.to_jax()
    cfg = pcsr.config
    return _call(arrs["colidx"], arrs["lrow"], arrs["trow"], arrs["vals"],
                 jnp.asarray(Q), jnp.asarray(K),
                 n_blocks=pcsr.n_blocks, R=cfg.R, W=cfg.W, V=cfg.V,
                 K=pcsr.K, dblk=cfg.dblk, interpret=interpret)
