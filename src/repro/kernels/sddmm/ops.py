"""Jit'd wrappers around the SDDMM Pallas kernels.

Two entry points, both multi-head aware (rank-3 ``(H, n, d)`` operands run
every head in ONE kernel call over head-tiled PCSR steering arrays — see
``PCSR.head_tiled`` — so multi-head GAT compiles once):

* ``sddmm(pcsr, Q, K)`` — raw masked edge scores in slot layout;
* ``sddmm_softmax_stats(pcsr, Q, K)`` — the fused GAT attention front
  half in *stats form*: one kernel pass producing raw logits (masked
  slots −inf) + per-row online-softmax stats, consumed directly by the
  ParamSpMM softmax prologue — ZERO elementwise passes between the two
  kernels of the GAT forward;
* ``sddmm_softmax(pcsr, Q, K)`` — the materialized-α reference form
  (stats pass + one elementwise normalize), kept for validation and for
  callers that genuinely need α as a tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pcsr import PCSR, LANES, SUBLANES
from repro.kernels.paramspmm.ops import _pad_cols


def stats_rows(n_blocks: int) -> int:
    """Leading extent of the tile-aligned stats layout: one full
    ``(SUBLANES, LANES)`` f32 tile per output block."""
    return n_blocks * SUBLANES


def unpack_stats(stats, R: int):
    """Dense ``(..., n_blocks, R)`` view of tile-aligned kernel stats.

    The fused SDDMM keeps per-row softmax stats in one aligned
    ``(SUBLANES, LANES)`` tile per block — row r of block b at
    ``[b·SUBLANES, r]`` — so the stats BlockSpec is exactly one hardware
    tile and compiles on real TPU.  Plain-JAX consumers (the reference
    normalize, the flash-recompute backward, the distributed GAT
    branches) call this to recover the dense view."""
    lead = stats.shape[:-2]
    nb = stats.shape[-2] // SUBLANES
    return stats.reshape(lead + (nb, SUBLANES, LANES))[..., 0, :R]


def pack_stats(dense, R: int):
    """Inverse of ``unpack_stats``: lay a dense ``(..., n_blocks, R)``
    per-row stat onto the kernel's tile-aligned layout (zeros elsewhere —
    only sublane 0 / lanes < R are ever read)."""
    lead = dense.shape[:-2]
    nb = dense.shape[-2]
    out = jnp.zeros(lead + (nb, SUBLANES, LANES), dense.dtype)
    out = out.at[..., 0, :R].set(dense)
    return out.reshape(lead + (nb * SUBLANES, LANES))


def _pad_q(Q, n_rows_pad: int, dblk: int):
    """Pad a (..., n, d) query stack to (..., n_rows_pad, J·dblk) rows/lanes."""
    Qp, _ = _pad_cols(Q.reshape(-1, Q.shape[-1]), dblk)
    Qp = Qp.reshape(Q.shape[:-1] + (Qp.shape[-1],))
    pad = [(0, 0)] * (Q.ndim - 2) + [(0, n_rows_pad - Q.shape[-2]), (0, 0)]
    return jnp.pad(Qp, pad)


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "W", "V", "K", "dblk", "interpret"))
def _call(colidx, lrow, trow, vals, Q, K_mat, *, H, n_blocks, R, W, V, K,
          dblk, interpret):
    from .kernel import sddmm_kernel
    Qp = _pad_q(Q, n_blocks * R, dblk).reshape(H * n_blocks * R, -1)
    Kp, _ = _pad_cols(K_mat.reshape(-1, K_mat.shape[-1]), dblk)
    scores = sddmm_kernel(colidx, lrow, trow, Qp, Kp,
                          W=W, V=V, K=K, dblk=dblk, interpret=interpret)
    # sampling mask: padding slots (and explicit zeros) score exactly 0,
    # matching the dense oracle's (A ≠ 0) ⊙ (Q·Kᵀ)
    return jnp.where(vals != 0, scores, 0.0)


def sddmm(pcsr: PCSR, Q, K, *, interpret: bool = True):
    """E = (A≠0) ⊙ (Q·Kᵀ) in PCSR slot layout. Pallas path.

    ``Q``/``K`` of shape (n, d) return (C, V, K) slots; (H, n, d) stacks
    return (H, C, V, K) — all heads in a single head-tiled kernel call.
    """
    Q = jnp.asarray(Q)
    K_mat = jnp.asarray(K)
    single = Q.ndim == 2
    if single:
        Q, K_mat = Q[None], K_mat[None]
    H = Q.shape[0]
    t = pcsr.head_tiled(H)
    cfg = pcsr.config
    scores = _call(t["colidx"], t["lrow"], t["trow"], t["vals"], Q, K_mat,
                   H=H, n_blocks=pcsr.n_blocks, R=cfg.R, W=cfg.W, V=cfg.V,
                   K=pcsr.K, dblk=cfg.dblk, interpret=interpret)
    scores = scores.reshape(H, pcsr.num_chunks, cfg.V, pcsr.K)
    return scores[0] if single else scores


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "W", "V", "K", "dblk", "scale", "slope",
    "interpret"))
def _stats_call(colidx, lrow, trow, init, vals, Q, K_mat, *, H, n_blocks, R,
                W, V, K, dblk, scale, slope, interpret):
    from .kernel import sddmm_softmax_kernel
    Qp = _pad_q(Q, n_blocks * R, dblk).reshape(H * n_blocks * R, -1)
    Kp, _ = _pad_cols(K_mat.reshape(-1, K_mat.shape[-1]), dblk)
    return sddmm_softmax_kernel(
        colidx, lrow, trow, init, vals, Qp, Kp,
        n_blocks=H * n_blocks, W=W, V=V, K=K, dblk=dblk,
        scale=scale, slope=slope, interpret=interpret)


def normalize_from_stats(logits, rowmax, rowsum, lrow, trow, *, R, V, K):
    """The *unfused* normalize epilogue: slot → row stats gather + one exp
    and one divide per slot.  The GAT hot path does NOT run this — the
    fused ParamSpMM prologue consumes (logits, rowmax, rowsum) directly.
    It is the ONE shared α-from-stats implementation (masked-slot −inf /
    empty-row guard convention): the reference path behind
    ``sddmm_softmax`` AND the flash-style α recompute in the GAT backward
    (``core.engine.make_gat_message_fn``) — keep the guards here only."""
    C = trow.shape[0]
    rows = (trow[:, None, None].astype(jnp.int32) * R
            + lrow.reshape(C, 1, K) * V
            + jnp.arange(V, dtype=jnp.int32)[None, :, None])
    # Fully-masked/empty rows hold rowmax = −inf, rowsum = 0 — or outright
    # garbage (NaN included) when their block was never visited by the
    # SDDMM.  Both guards must be NaN-proof: ``isfinite`` rejects NaN and
    # ±inf, and ``rowsum > 0`` is False for NaN, so such rows normalize
    # against (0, 1) and their −inf logits come out exactly α = 0 — a
    # ``maximum(rowsum, eps)`` denominator would propagate NaN instead.
    rm = rowmax.reshape(-1)
    rm = jnp.where(jnp.isfinite(rm), rm, 0.0)
    rs = rowsum.reshape(-1)
    denom = jnp.where((rs > 0) & jnp.isfinite(rs), rs, 1.0)
    # masked/padding slots carry logit −inf → exp(−inf − finite) = 0 exact
    return jnp.exp(logits - rm[rows]) / denom[rows]


def sddmm_softmax_stats(pcsr: PCSR, Q, K, *, scale: float | None = None,
                        slope: float = 0.2, interpret: bool = True):
    """The fused GAT attention front half, *stats form*: one kernel pass
    returning ``(logits, rowmax, rowsum)`` — raw post-LeakyReLU logits in
    slot layout (masked slots −inf) plus the per-row online-softmax
    statistics, exactly the operands ``paramspmm_with_vals(stats=...)``
    consumes in its prologue.  No elementwise normalize runs anywhere:
    the two-kernel GAT forward and the flash-style recompute backward are
    built on this.

    ``scale`` defaults to 1/√d.  Shapes: logits (C, V, K) per (n, d)
    inputs, (H, C, V, K) per (H, n, d); rowmax/rowsum are always the
    kernel-native tile-aligned ``(H·n_blocks·SUBLANES, LANES)`` layout
    (head-tiled blocks, one (8, 128) tile per block) — ``unpack_stats``
    recovers the dense ``(H·n_blocks, R)`` view.
    """
    Q = jnp.asarray(Q)
    K_mat = jnp.asarray(K)
    single = Q.ndim == 2
    if single:
        Q, K_mat = Q[None], K_mat[None]
    H = Q.shape[0]
    if scale is None:
        scale = float(1.0 / np.sqrt(Q.shape[-1]))
    t = pcsr.steering(H)
    cfg = pcsr.config
    logits, rowmax, rowsum = _stats_call(
        t["colidx"], t["lrow"], t["trow"], t["init"], t["vals"], Q, K_mat,
        H=H, n_blocks=pcsr.n_blocks, R=cfg.R, W=cfg.W, V=cfg.V, K=pcsr.K,
        dblk=cfg.dblk, scale=float(scale), slope=float(slope),
        interpret=interpret)
    logits = logits.reshape(H, pcsr.num_chunks, cfg.V, pcsr.K)
    if single:
        logits = logits[0]
    return logits, rowmax, rowsum


@functools.partial(jax.jit, static_argnames=("R", "V", "K", "H"))
def _normalize_heads(logits, rowmax, rowsum, lrow, trow, *, R, V, K, H):
    f = lambda lg, rm, rs: normalize_from_stats(lg, rm, rs, lrow, trow,
                                                R=R, V=V, K=K)
    rm = unpack_stats(rowmax, R)              # (H·n_blocks, R) dense view
    rs = unpack_stats(rowsum, R)
    if H == 1:
        return f(logits[0], rm, rs)[None]
    return jax.vmap(f)(logits, rm.reshape(H, -1, R), rs.reshape(H, -1, R))


def sddmm_softmax(pcsr: PCSR, Q, K, *, scale: float | None = None,
                  slope: float = 0.2, interpret: bool = True,
                  with_logits: bool = False):
    """Fused GAT attention weights: softmax_row(LeakyReLU(scale·Q·Kᵀ)) on
    A's sparsity pattern, in PCSR slot layout. Pallas path.

    This is the *materialized-α* form (kernel pass + one elementwise
    normalize): the reference/unfused path.  The GAT hot path uses
    ``sddmm_softmax_stats`` + the SpMM softmax prologue instead and never
    materializes α.  ``scale`` defaults to 1/√d.  Returns ``alpha`` — or
    ``(alpha, logits)`` with ``with_logits=True``, where ``logits`` are the
    post-LeakyReLU scores (masked slots −inf).  Shapes follow ``sddmm``:
    (C, V, K) per (n, d) inputs, (H, C, V, K) per (H, n, d).
    """
    Q = jnp.asarray(Q)
    single = Q.ndim == 2
    logits, rowmax, rowsum = sddmm_softmax_stats(
        pcsr, Q, K, scale=scale, slope=slope, interpret=interpret)
    H = 1 if single else Q.shape[0]
    cfg = pcsr.config
    t = pcsr.steering()           # single-head slot→row map suffices: the
    # head-tiled rows are the single-head rows offset per head
    lg = logits[None] if single else logits
    alpha = _normalize_heads(lg, rowmax, rowsum,
                             jnp.asarray(t["lrow"]), jnp.asarray(t["trow"]),
                             R=cfg.R, V=cfg.V, K=pcsr.K, H=H)
    alpha = alpha[0] if single else alpha
    return (alpha, logits) if with_logits else alpha
