"""Pure-jnp oracle for the selective scan (the mamba_branch core)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dA, dBx, C):
    """dA/dBx (B, S, N, Di), C (B, S, N) → y (B, S, Di) via the
    associative scan the model path uses."""
    def combine(a, b):
        return a[0] * b[0], b[0] * a[1] + b[1]

    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return jnp.einsum("bsnd,bsn->bsd", hs, C)
