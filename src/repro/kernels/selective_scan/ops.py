"""Jit'd wrapper: padding + layout for the selective-scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import selective_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "tile", "interpret"))
def selective_scan(dA, dBx, C, *, chunk: int = 128, tile: int = 128,
                   interpret: bool = True):
    """dA/dBx (B, S, N, Di), C (B, S, N) → y (B, S, Di).  Pads S to the
    chunk and Di to the lane tile (dA=1, dBx=0 padding is recurrence-
    neutral; padded Di columns are sliced off)."""
    B, S, N, Di = dA.shape
    sp = -(-S // chunk) * chunk
    dp = -(-Di // tile) * tile
    if sp != S:
        pad = ((0, 0), (0, sp - S), (0, 0), (0, 0))
        dA = jnp.pad(dA, pad, constant_values=1.0)
        dBx = jnp.pad(dBx, pad)
        C = jnp.pad(C, ((0, 0), (0, sp - S), (0, 0)))
    if dp != Di:
        pad = ((0, 0), (0, 0), (0, 0), (0, dp - Di))
        dA = jnp.pad(dA, pad, constant_values=1.0)
        dBx = jnp.pad(dBx, pad)
    y = selective_scan_kernel(dA.astype(jnp.float32),
                              dBx.astype(jnp.float32),
                              C.astype(jnp.float32),
                              chunk=chunk, tile=tile, interpret=interpret)
    return y[:, :S, :Di]
