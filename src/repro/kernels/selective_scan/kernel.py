"""Fused selective-scan (mamba recurrence) Pallas TPU kernel.

Identified in EXPERIMENTS.md §Perf (hymba cell): the XLA
``associative_scan`` materializes the (B,S,Di,N) hidden-state tensor at
every combine level (~log2(S) HBM round-trips).  This kernel runs the
recurrence sequentially over sequence chunks with the state resident in a
VMEM scratch and fuses the C-contraction, so the hidden states NEVER
reach HBM: traffic = read dA/dBx/C once + write y once (the memory-term
floor).

    h_t = dA_t ⊙ h_{t-1} + dBx_t          (N, Di) per (batch, tile)
    y_t = Σ_n h_t[n, :] · C_t[n]

Layout: Di innermost (lanes, 128-tiled); N on sublanes.  Grid
(B, Di-tiles, S-chunks), sequence innermost so the scratch state carries
across consecutive chunk steps; reset at chunk 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(da_ref, dbx_ref, c_ref, y_ref, h_scratch, *, chunk: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _reset():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    def step(t, h):
        da = da_ref[0, t]                        # (N, tile)
        dbx = dbx_ref[0, t]
        c = c_ref[0, t]                          # (N,)
        h = da * h + dbx
        y_ref[0, t, :] = jnp.sum(h * c[:, None], axis=0)
        return h

    h_scratch[...] = jax.lax.fori_loop(0, chunk, step, h_scratch[...])


def selective_scan_kernel(dA, dBx, C, *, chunk: int = 128, tile: int = 128,
                          interpret: bool = True):
    """dA/dBx (B, S, N, Di) f32, C (B, S, N) f32 → y (B, S, Di) f32.
    S must divide by ``chunk`` and Di by ``tile`` (ops.py pads)."""
    B, S, N, Di = dA.shape
    assert S % chunk == 0 and Di % tile == 0
    grid = (B, Di // tile, S // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, N, tile), lambda b, d, s: (b, s, 0, d)),
            pl.BlockSpec((1, chunk, N, tile), lambda b, d, s: (b, s, 0, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, tile), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, tile), jnp.float32)],
        interpret=interpret,
        name=f"selective_scan_c{chunk}_t{tile}",
    )(dA, dBx, C)
