from .ops import selective_scan
from .ref import selective_scan_ref
