"""ParamSpMM Pallas TPU kernel (paper Alg. 2, TPU adaptation per DESIGN.md §2)
with fused prologue / epilogue.

Grid ``(J, C, K)`` = (dim-tiles, chunks, slots).  Scalar-prefetched
``colidx`` drives the gather of one ``(1, Dblk)`` row of ``B`` per step via
``B``'s BlockSpec index map — the TPU-idiomatic replacement for the CUDA
warp's irregular global load.  The ``(R, Dblk)`` output block is revisited
across consecutive steps with the same ``trow`` and accumulated in VMEM:
with ``S=True`` several chunks target one block (the paper's ``TRow`` +
``atomicAdd``, made race-free by the sequential grid).

Parameter mapping (paper → here):
  V → rows fed per gathered B row (vals block ``(1, V, K)``);
  F → ``Dblk = F·128`` lanes per step (thread coarsening);
  W → ``R = V·W`` output-block rows;
  S → chunking policy baked into the PCSR arrays (kernel is agnostic).

Fusion (this file's reason to exist beyond the plain gather-scatter):

* **Softmax prologue** (``prologue=True``): ``vals`` carries raw attention
  *logits* (masked slots = −inf) and two extra tile-aligned
  ``(n_blocks·SUBLANES, LANES)`` inputs — one (8, 128) tile per block,
  row stats in sublane 0 — carry the per-row online-softmax stats the
  fused SDDMM produced (its native output layout, aligned so the fused
  path compiles on real TPU, not just in interpret mode).  The
  attention weight α = exp(logit − rowmax)/rowsum is computed in-register
  while the gathered B row is being consumed — the interstitial
  elementwise normalize pass between SDDMM and SpMM disappears, making
  the GAT forward exactly TWO kernels.
* **Epilogue** (``scale``/``bias``/``residual``/``activation``): on the
  last ``(j, k)`` visit of each output block — ``fini[c] == 1 and
  k == K−1``, the moment the completed ``(R, Dblk)`` tile is still
  VMEM-resident — a per-row degree-norm scale, per-feature bias add,
  dense residual add (the matching ``(R, Dblk)`` tile of a full
  ``(n, d)`` operand — GIN's ``(1+ε)h`` term), and activation are
  applied before write-back, so a GCN aggregation step (and a GIN
  ``(1+ε)h + A·h`` aggregation) is ONE kernel instead of kernel + 2–3
  XLA elementwise passes over the (n, d) output.

Padding-slot safety under the prologue: a masked/padding slot carries
logit = −inf, so exp(−inf − m) = 0 regardless of the row stats — even the
garbage stats of never-visited rows (the ``isfinite``/``> 0`` guards keep
the 0 exact instead of NaN).  Coverage chunks for empty blocks (see
``PCSR.steering(covered=True)``) therefore accumulate exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pcsr import LANES, SUBLANES

ACTIVATIONS = ("none", "relu", "leaky_relu")


def _kernel(colidx_ref, lrow_ref, trow_ref, init_ref, fini_ref,  # prefetch
            *refs, V: int, K: int, prologue: bool, has_scale: bool,
            has_bias: bool, has_resid: bool, activation: str, slope: float):
    c = pl.program_id(1)
    k = pl.program_id(2)

    it = iter(refs)
    vals_ref, b_ref = next(it), next(it)
    rowmax_ref = next(it) if prologue else None
    rowsum_ref = next(it) if prologue else None
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    resid_ref = next(it) if has_resid else None
    out_ref = next(it)

    # First visit of this output block in this dim-tile pass → zero it.
    @pl.when((k == 0) & (init_ref[c] == 1))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lr = lrow_ref[c * K + k]                 # panel within block
    row = lr * V
    vv = vals_ref[0, :, k]                   # (V,) values — or raw logits
    if prologue:
        # α = exp(logit − rowmax)/rowsum in-register (flash-style): the
        # stats block for trow[c] is VMEM-resident across the chunk.
        # Guards: empty rows have rowmax = −inf / rowsum = 0 (or garbage
        # when the row's block was never visited by the SDDMM); masked and
        # padding slots have logit = −inf, so α must come out exactly 0.
        m = rowmax_ref[0, pl.ds(row, V)]
        s = rowsum_ref[0, pl.ds(row, V)]
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        den = jnp.where(s > 0, s, 1.0)
        vv = jnp.exp(vv - m) / den
    brow = b_ref[0, :]                       # (Dblk,) gathered B row
    acc = out_ref[pl.ds(row, V), :]
    out_ref[pl.ds(row, V), :] = acc + vv[:, None].astype(brow.dtype) * brow[None, :]

    if has_scale or has_bias or has_resid or activation != "none":
        # Last (j, k) visit of this output block → the accumulated
        # (R, Dblk) tile is complete for this dim tile; apply the fused
        # epilogue while it is still VMEM-resident.
        @pl.when((k == K - 1) & (fini_ref[c] == 1))
        def _epilogue():
            y = out_ref[...]
            if has_scale:
                # per-row scales live in sublane 0, lanes 0..R−1 of the
                # block's aligned stats tile
                sc = scale_ref[0, pl.ds(0, y.shape[0])]
                y = y * sc[:, None].astype(y.dtype)
            if has_bias:
                y = y + bias_ref[0, :][None, :].astype(y.dtype)
            if has_resid:
                # the residual operand's matching (R, Dblk) tile
                y = y + resid_ref[...].astype(y.dtype)
            if activation == "relu":
                y = jnp.maximum(y, 0.0)
            elif activation == "leaky_relu":
                y = jnp.where(y >= 0, y, slope * y)
            out_ref[...] = y


def paramspmm_kernel(colidx, lrow, trow, init, fini, vals, B_padded, *,
                     n_blocks: int, R: int, V: int, K: int, dblk: int,
                     rowmax=None, rowsum=None, scale=None, bias=None,
                     residual=None, activation: str = "none",
                     slope: float = 0.2, interpret: bool = True):
    """Invoke the Pallas kernel on pre-padded operands.

    B_padded: (n_b, J·dblk).  Returns C_padded (n_blocks·R, J·dblk).

    Optional fusion operands — the per-row ones all use the tile-aligned
    stats layout ``(n_blocks·SUBLANES, LANES)`` (one (8, 128) f32 tile per
    block, row r of block b at ``[b·SUBLANES, r]``), so every BlockSpec is
    a whole hardware tile and the fused path compiles on real TPU:
      rowmax/rowsum — softmax prologue stats (vals = logits), the fused
                      SDDMM's native output layout;
      scale         — per-row epilogue scale (degree norm), packed by
                      ``ops._pack_scale``;
      bias (SUBLANES, J·dblk) — per-feature epilogue bias (row 0 real);
      residual (n_blocks·R, J·dblk) — dense epilogue addend in the
                      output's own padded block layout; each output
                      block's last visit adds its matching (R, Dblk)
                      tile (GIN's ``(1+ε)h`` term);
      activation    — "none" | "relu" | "leaky_relu" epilogue.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"activation {activation!r} not in {ACTIVATIONS}")
    assert R <= LANES, f"R={R} must fit one stats-tile lane row"
    stats_shape = (n_blocks * SUBLANES, LANES)
    for name, arr in (("rowmax", rowmax), ("rowsum", rowsum),
                      ("scale", scale)):
        assert arr is None or arr.shape == stats_shape, (
            f"{name} must be tile-aligned {stats_shape}, got {arr.shape}")
    C = trow.shape[0]
    dim_pad = B_padded.shape[1]
    assert dim_pad % dblk == 0
    assert bias is None or bias.shape == (SUBLANES, dim_pad), (
        f"bias must be ({SUBLANES}, {dim_pad}), got {bias.shape}")
    J = dim_pad // dblk
    grid = (J, C, K)
    prologue = rowmax is not None

    in_specs = [
        # whole chunk's vals; index map constant in k → fetched once/chunk
        pl.BlockSpec((1, V, K), lambda j, c, k, ci, lr, tr, it, fi: (c, 0, 0)),
        # the gather: B row chosen by the scalar-prefetched colidx
        pl.BlockSpec((1, dblk),
                     lambda j, c, k, ci, lr, tr, it, fi: (ci[c * K + k], j)),
    ]
    operands = [vals, B_padded]
    if prologue:
        stats_spec = pl.BlockSpec(
            (SUBLANES, LANES), lambda j, c, k, ci, lr, tr, it, fi: (tr[c], 0))
        in_specs += [stats_spec, stats_spec]
        operands += [rowmax, rowsum]
    if scale is not None:
        in_specs.append(pl.BlockSpec(
            (SUBLANES, LANES), lambda j, c, k, ci, lr, tr, it, fi: (tr[c], 0)))
        operands.append(scale)
    if bias is not None:
        in_specs.append(pl.BlockSpec(
            (SUBLANES, dblk), lambda j, c, k, ci, lr, tr, it, fi: (0, j)))
        operands.append(bias)
    if residual is not None:
        assert residual.shape == (n_blocks * R, dim_pad), (
            f"residual must match the padded output "
            f"({n_blocks * R}, {dim_pad}), got {residual.shape}")
        in_specs.append(pl.BlockSpec(
            (R, dblk), lambda j, c, k, ci, lr, tr, it, fi: (tr[c], j)))
        operands.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((R, dblk),
                               lambda j, c, k, ci, lr, tr, it, fi: (tr[c], j)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, V=V, K=K, prologue=prologue,
                          has_scale=scale is not None,
                          has_bias=bias is not None,
                          has_resid=residual is not None,
                          activation=activation, slope=slope),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * R, dim_pad), B_padded.dtype),
        interpret=interpret,
        name=f"paramspmm_v{V}_k{K}_r{R}_d{dblk}"
             f"{'_pro' if prologue else ''}"
             f"{'_res' if residual is not None else ''}"
             f"{'' if activation == 'none' else '_' + activation}",
    )
    return fn(colidx, lrow, trow, init, fini, *operands)
