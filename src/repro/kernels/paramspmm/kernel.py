"""ParamSpMM Pallas TPU kernel (paper Alg. 2, TPU adaptation per DESIGN.md §2).

Grid ``(J, C, K)`` = (dim-tiles, chunks, slots).  Scalar-prefetched
``colidx`` drives the gather of one ``(1, Dblk)`` row of ``B`` per step via
``B``'s BlockSpec index map — the TPU-idiomatic replacement for the CUDA
warp's irregular global load.  The ``(R, Dblk)`` output block is revisited
across consecutive steps with the same ``trow`` and accumulated in VMEM:
with ``S=True`` several chunks target one block (the paper's ``TRow`` +
``atomicAdd``, made race-free by the sequential grid).

Parameter mapping (paper → here):
  V → rows fed per gathered B row (vals block ``(1, V, K)``);
  F → ``Dblk = F·128`` lanes per step (thread coarsening);
  W → ``R = V·W`` output-block rows;
  S → chunking policy baked into the PCSR arrays (kernel is agnostic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(colidx_ref, lrow_ref, trow_ref, init_ref,   # scalar prefetch
            vals_ref, b_ref,                            # VMEM inputs
            out_ref,                                    # VMEM output
            *, V: int, K: int):
    c = pl.program_id(1)
    k = pl.program_id(2)

    # First visit of this output block in this dim-tile pass → zero it.
    @pl.when((k == 0) & (init_ref[c] == 1))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lr = lrow_ref[c * K + k]                 # panel within block
    vv = vals_ref[0, :, k]                   # (V,) vector values
    brow = b_ref[0, :]                       # (Dblk,) gathered B row
    row = lr * V
    acc = out_ref[pl.ds(row, V), :]
    out_ref[pl.ds(row, V), :] = acc + vv[:, None].astype(brow.dtype) * brow[None, :]


def paramspmm_kernel(colidx, lrow, trow, init, vals, B_padded, *,
                     n_blocks: int, R: int, V: int, K: int, dblk: int,
                     interpret: bool = True):
    """Invoke the Pallas kernel on pre-padded operands.

    B_padded: (n_b, J·dblk).  Returns C_padded (n_blocks·R, J·dblk).
    """
    C = trow.shape[0]
    dim_pad = B_padded.shape[1]
    assert dim_pad % dblk == 0
    J = dim_pad // dblk
    grid = (J, C, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            # whole chunk's vals; index map constant in k → fetched once/chunk
            pl.BlockSpec((1, V, K), lambda j, c, k, ci, lr, tr, it: (c, 0, 0)),
            # the gather: B row chosen by the scalar-prefetched colidx
            pl.BlockSpec((1, dblk),
                         lambda j, c, k, ci, lr, tr, it: (ci[c * K + k], j)),
        ],
        out_specs=pl.BlockSpec((R, dblk),
                               lambda j, c, k, ci, lr, tr, it: (tr[c], j)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, V=V, K=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * R, dim_pad), B_padded.dtype),
        interpret=interpret,
        name=f"paramspmm_v{V}_k{K}_r{R}_d{dblk}",
    )
    return fn(colidx, lrow, trow, init, vals, B_padded)
