"""Pure-jnp oracle for ParamSpMM: basic row-wise CSR SpMM (paper Alg. 1).

This is both the correctness reference for the Pallas kernel / JAX engine
and the "static kernel" baseline family (GE-SpMM-style CSR traversal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def spmm_ref(indptr, indices, data, B, n_rows: int):
    """C[n_rows, dim] = A · B with A given as CSR (gather + segment-sum)."""
    indptr = np.asarray(indptr)
    rows = np.repeat(np.arange(n_rows), np.diff(indptr))
    rows = jnp.asarray(rows, jnp.int32)
    indices = jnp.asarray(indices, jnp.int32)
    data = jnp.asarray(data, B.dtype)
    gathered = jnp.take(B, indices, axis=0)          # (nnz, dim)
    contrib = data[:, None] * gathered
    return jax.ops.segment_sum(contrib, rows, num_segments=n_rows)


def spmm_dense_ref(A_dense, B):
    """Dense oracle for small property tests."""
    return jnp.asarray(A_dense) @ jnp.asarray(B)
