from .ops import paramspmm, paramspmm_with_vals
from .ref import spmm_ref, spmm_dense_ref
