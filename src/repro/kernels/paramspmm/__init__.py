from .ops import paramspmm
from .ref import spmm_ref, spmm_dense_ref
