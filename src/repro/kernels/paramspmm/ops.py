"""Jit'd wrapper around the ParamSpMM Pallas kernel: padding, dispatch,
and the high-level ``paramspmm(pcsr, B)`` entry point.

All Pallas dispatch goes through *covered* steering arrays
(``PCSR.steering(covered=True)``): every output block — including empty
ones — is visited and zero-initialized by the kernel's own ``init`` path,
so no post-kernel unvisited-block mask pass (the old ``jnp.where`` +
``jnp.repeat`` over the full padded output) remains.

Fusion surface (see ``kernel.py``):

* ``paramspmm_with_vals(..., stats=(rowmax, rowsum))`` — softmax
  *prologue*: ``vals`` are raw logits (masked slots −inf) and α is
  computed in-register from the per-row stats.  The GAT hot path feeds
  the fused SDDMM's stats straight in: two kernels, zero interstitial
  elementwise pass.
* ``paramspmm(..., scale=, bias=, residual=, activation=)`` — fused
  *epilogue*: per-row degree-norm scale, per-feature bias, dense
  residual add (GIN's ``(1+ε)h`` operand), activation applied on the
  last visit of each VMEM-resident output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pcsr import PCSR, LANES, SUBLANES
from .kernel import paramspmm_kernel


def _pad_cols(B, dblk: int):
    dim = B.shape[1]
    dim_pad = -(-dim // dblk) * dblk
    if dim_pad != dim:
        B = jnp.pad(B, ((0, 0), (0, dim_pad - dim)))
    return B, dim_pad


def _pack_scale(x, n_blocks: int, R: int):
    """Pack a flat per-row vector (≤ n_blocks·R entries) into the kernel's
    tile-aligned per-row layout ``(n_blocks·SUBLANES, LANES)`` — one
    (8, 128) tile per block, row r of block b at ``[b·SUBLANES, r]``."""
    dense = jnp.pad(x.reshape(-1), (0, n_blocks * R - x.size)
                    ).reshape(n_blocks, R)
    out = jnp.zeros((n_blocks, SUBLANES, LANES), x.dtype)
    out = out.at[:, 0, :R].set(dense)
    return out.reshape(n_blocks * SUBLANES, LANES)


@functools.partial(jax.jit, static_argnames=(
    "n_blocks", "R", "V", "K", "dblk", "n_rows", "dim", "activation",
    "interpret"))
def _call(colidx, lrow, trow, init, fini, vals, B, rowmax=None, rowsum=None,
          scale=None, bias=None, residual=None, *, n_blocks, R, V, K, dblk,
          n_rows, dim, activation="none", interpret):
    """Pallas dispatch on pre-packed (covered) steering arrays.

    ``scale`` is a flat per-row vector (≤ n_blocks·R entries), ``bias`` a
    flat per-feature vector (≤ dim entries), ``residual`` a dense
    ``(≤ n_rows, dim)`` addend; all are padded here to the kernel's
    tile-aligned block shapes.  ``rowmax``/``rowsum`` are the
    online-softmax stats from the fused SDDMM (vals = raw logits) in its
    native tile-aligned ``(n_blocks·SUBLANES, LANES)`` layout — asserted
    here so a dense ``(n_blocks, R)`` array (which only interpret mode
    would tolerate) fails loudly at trace time.
    """
    stats_shape = (n_blocks * SUBLANES, LANES)
    for name, arr in (("rowmax", rowmax), ("rowsum", rowsum)):
        assert arr is None or arr.shape == stats_shape, (
            f"{name} must be tile-aligned {stats_shape} "
            f"(the fused SDDMM's native layout), got {arr.shape}")
    B_padded, dim_pad = _pad_cols(B, dblk)
    if scale is not None:
        scale = _pack_scale(scale, n_blocks, R)
    if bias is not None:
        bias = jnp.pad(bias.reshape(-1), (0, dim_pad - bias.size))[None, :]
        bias = jnp.pad(bias, ((0, SUBLANES - 1), (0, 0)))   # tile-aligned
    if residual is not None:
        residual = jnp.pad(residual,
                           ((0, n_blocks * R - residual.shape[0]),
                            (0, dim_pad - residual.shape[1])))
    out = paramspmm_kernel(colidx, lrow, trow, init, fini, vals, B_padded,
                           n_blocks=n_blocks, R=R, V=V, K=K, dblk=dblk,
                           rowmax=rowmax, rowsum=rowsum, scale=scale,
                           bias=bias, residual=residual,
                           activation=activation, interpret=interpret)
    return out[:n_rows, :dim]


def paramspmm(pcsr: PCSR, B, *, scale=None, bias=None, residual=None,
              activation: str = "none", interpret: bool = True):
    """C = act(scale ⊙ (A·B) + bias + residual) where A is held as PCSR —
    the epilogue operands default to the identity (plain A·B).  Pallas
    path (interpret on CPU)."""
    return paramspmm_with_vals(pcsr, None, B, scale=scale, bias=bias,
                               residual=residual, activation=activation,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "V", "K", "dblk", "n_rows", "dim", "activation",
    "interpret"))
def _call_heads(colidx, lrow, trow, init, fini, vals, B, rowmax=None,
                rowsum=None, *, H, n_blocks, R, V, K, dblk, n_rows, dim,
                activation="none", interpret):
    out = _call(colidx, lrow, trow, init, fini,
                vals.reshape((H * vals.shape[1],) + vals.shape[2:]),
                B.reshape(H * B.shape[1], B.shape[2]),
                rowmax, rowsum,
                n_blocks=H * n_blocks, R=R, V=V, K=K, dblk=dblk,
                n_rows=H * n_blocks * R, dim=dim, activation=activation,
                interpret=interpret)
    return out.reshape(H, n_blocks * R, dim)[:, :n_rows]


def _pad_chunk_vals(vals, n_extra: int, fill: float):
    """Append ``n_extra`` coverage chunks to a (..., C, V, K) slot tensor."""
    if n_extra == 0:
        return vals
    pad = [(0, 0)] * vals.ndim
    pad[-3] = (0, n_extra)
    return jnp.pad(vals, pad, constant_values=fill)


def paramspmm_with_vals(pcsr: PCSR, vals, B, *, stats=None, scale=None,
                        bias=None, residual=None, activation: str = "none",
                        interpret: bool = True):
    """SpMM over A's *pattern* with per-slot values supplied at call time —
    the aggregation step of attention GNNs, where the PCSR topology is fixed
    but the edge weights change every step.  ``vals=None`` uses the values
    stored in the PCSR.

    ``stats=(rowmax, rowsum)`` enables the fused softmax **prologue**:
    ``vals`` are then the raw logits from ``sddmm_softmax_stats`` (masked
    slots −inf) and α = exp(logit − rowmax)/rowsum is computed in-register —
    no interstitial normalize pass.  Stats use the fused SDDMM's native
    tile-aligned layout ``(n_blocks·SUBLANES, LANES)`` single-head,
    ``(H·n_blocks·SUBLANES, LANES)`` multi-head (one (8, 128) tile per
    head-tiled block; ``repro.kernels.sddmm.ops.unpack_stats`` gives the
    dense view).

    ``scale``/``bias``/``residual``/``activation`` enable the fused
    **epilogue** (single-head only): per-row scale (flat, ≤ n_rows),
    per-feature bias (flat, ≤ dim), dense residual addend ((n, dim) —
    GIN's ``(1+ε)h`` term rides the VMEM-resident output block), then
    activation, applied inside the kernel on the last visit of each
    output block.

    Multi-head: ``vals`` of shape (H, C, V, K) with ``B`` of shape
    (H, n, d) run all heads in one kernel call over head-tiled steering
    arrays (``PCSR.steering``) and return (H, n_rows, d) — one
    compilation for the whole head batch.
    """
    cfg = pcsr.config
    B = jnp.asarray(B)
    if stats is not None and vals is None:
        # the prologue interprets vals as logits; stored edge weights (and
        # the 0-valued coverage chunks) are NOT logits — exp(0 − stat)
        # would silently turn padding into weight
        raise ValueError("stats= requires explicit logits as vals "
                         "(from sddmm_softmax_stats), not the stored "
                         "PCSR values")
    fill = -jnp.inf if stats is not None else 0.0
    rowmax, rowsum = stats if stats is not None else (None, None)
    if B.ndim == 3:                       # (H, n, d) head batch
        if (scale is not None or bias is not None or residual is not None
                or activation != "none"):
            raise NotImplementedError("epilogue fusion is single-head")
        H = B.shape[0]
        t = pcsr.steering(H, covered=True)
        C_cov = t["trow"].shape[0] // H
        if vals is None:                  # stored values, same for each head
            vals = t["vals"].reshape(H, C_cov, cfg.V, pcsr.K)
        else:
            vals = jnp.asarray(vals)
            if vals.ndim != 4 or vals.shape[0] != H:
                raise ValueError(f"multi-head vals must be (H={H}, C, V, K), "
                                 f"got {vals.shape}")
            vals = _pad_chunk_vals(vals, C_cov - vals.shape[1], fill)
        return _call_heads(t["colidx"], t["lrow"], t["trow"], t["init"],
                           t["fini"], vals, B, rowmax, rowsum, H=H,
                           n_blocks=pcsr.n_blocks, R=cfg.R, V=cfg.V,
                           K=pcsr.K, dblk=cfg.dblk, n_rows=pcsr.n_rows,
                           dim=B.shape[2], interpret=interpret)
    t = pcsr.steering(covered=True)
    C_cov = t["trow"].shape[0]
    if vals is None:
        vals = t["vals"]
    else:
        vals = _pad_chunk_vals(jnp.asarray(vals),
                               C_cov - jnp.shape(vals)[-3], fill)
    return _call(t["colidx"], t["lrow"], t["trow"], t["init"], t["fini"],
                 vals, B, rowmax, rowsum,
                 None if scale is None else jnp.asarray(scale),
                 None if bias is None else jnp.asarray(bias),
                 None if residual is None else jnp.asarray(residual),
                 n_blocks=pcsr.n_blocks, R=cfg.R, V=cfg.V, K=pcsr.K,
                 dblk=cfg.dblk, n_rows=pcsr.n_rows, dim=B.shape[1],
                 activation=activation, interpret=interpret)
