"""Jit'd wrapper around the ParamSpMM Pallas kernel: padding, dispatch,
and the high-level ``paramspmm(pcsr, B)`` entry point.

All Pallas dispatch goes through *covered* steering arrays
(``PCSR.steering(covered=True)``): every output block — including empty
ones — is visited and zero-initialized by the kernel's own ``init`` path,
so no post-kernel unvisited-block mask pass (the old ``jnp.where`` +
``jnp.repeat`` over the full padded output) remains.

Fusion surface (see ``kernel.py``):

* ``paramspmm_with_vals(..., stats=(rowmax, rowsum))`` — softmax
  *prologue*: ``vals`` are raw logits (masked slots −inf) and α is
  computed in-register from the per-row stats.  The GAT hot path feeds
  the fused SDDMM's stats straight in: two kernels, zero interstitial
  elementwise pass.
* ``paramspmm(..., scale=, bias=, activation=)`` — fused *epilogue*:
  per-row degree-norm scale, per-feature bias, activation applied on the
  last visit of each VMEM-resident output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pcsr import PCSR, LANES
from .kernel import paramspmm_kernel


def _pad_cols(B, dblk: int):
    dim = B.shape[1]
    dim_pad = -(-dim // dblk) * dblk
    if dim_pad != dim:
        B = jnp.pad(B, ((0, 0), (0, dim_pad - dim)))
    return B, dim_pad


def _pad_rows_2d(x, n_rows: int):
    """Pad/reshape a flat per-row vector to the kernel's (n_blocks, R)."""
    return jnp.pad(x.reshape(-1), (0, n_rows - x.size))


@functools.partial(jax.jit, static_argnames=(
    "n_blocks", "R", "V", "K", "dblk", "n_rows", "dim", "activation",
    "interpret"))
def _call(colidx, lrow, trow, init, fini, vals, B, rowmax=None, rowsum=None,
          scale=None, bias=None, *, n_blocks, R, V, K, dblk, n_rows, dim,
          activation="none", interpret):
    """Pallas dispatch on pre-packed (covered) steering arrays.

    ``scale`` is a flat per-row vector (≤ n_blocks·R entries), ``bias`` a
    flat per-feature vector (≤ dim entries); both are padded here to the
    kernel's block shapes.  ``rowmax``/``rowsum`` are the (n_blocks, R)
    online-softmax stats from the fused SDDMM (vals = raw logits).
    """
    B_padded, dim_pad = _pad_cols(B, dblk)
    if scale is not None:
        scale = _pad_rows_2d(scale, n_blocks * R).reshape(n_blocks, R)
    if bias is not None:
        bias = jnp.pad(bias.reshape(-1), (0, dim_pad - bias.size))[None, :]
    out = paramspmm_kernel(colidx, lrow, trow, init, fini, vals, B_padded,
                           n_blocks=n_blocks, R=R, V=V, K=K, dblk=dblk,
                           rowmax=rowmax, rowsum=rowsum, scale=scale,
                           bias=bias, activation=activation,
                           interpret=interpret)
    return out[:n_rows, :dim]


def paramspmm(pcsr: PCSR, B, *, scale=None, bias=None,
              activation: str = "none", interpret: bool = True):
    """C = act(scale ⊙ (A·B) + bias) where A is held as PCSR — the
    epilogue operands default to the identity (plain A·B).  Pallas path
    (interpret on CPU)."""
    return paramspmm_with_vals(pcsr, None, B, scale=scale, bias=bias,
                               activation=activation, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "V", "K", "dblk", "n_rows", "dim", "activation",
    "interpret"))
def _call_heads(colidx, lrow, trow, init, fini, vals, B, rowmax=None,
                rowsum=None, *, H, n_blocks, R, V, K, dblk, n_rows, dim,
                activation="none", interpret):
    out = _call(colidx, lrow, trow, init, fini,
                vals.reshape((H * vals.shape[1],) + vals.shape[2:]),
                B.reshape(H * B.shape[1], B.shape[2]),
                rowmax, rowsum,
                n_blocks=H * n_blocks, R=R, V=V, K=K, dblk=dblk,
                n_rows=H * n_blocks * R, dim=dim, activation=activation,
                interpret=interpret)
    return out.reshape(H, n_blocks * R, dim)[:, :n_rows]


def _pad_chunk_vals(vals, n_extra: int, fill: float):
    """Append ``n_extra`` coverage chunks to a (..., C, V, K) slot tensor."""
    if n_extra == 0:
        return vals
    pad = [(0, 0)] * vals.ndim
    pad[-3] = (0, n_extra)
    return jnp.pad(vals, pad, constant_values=fill)


def paramspmm_with_vals(pcsr: PCSR, vals, B, *, stats=None, scale=None,
                        bias=None, activation: str = "none",
                        interpret: bool = True):
    """SpMM over A's *pattern* with per-slot values supplied at call time —
    the aggregation step of attention GNNs, where the PCSR topology is fixed
    but the edge weights change every step.  ``vals=None`` uses the values
    stored in the PCSR.

    ``stats=(rowmax, rowsum)`` enables the fused softmax **prologue**:
    ``vals`` are then the raw logits from ``sddmm_softmax_stats`` (masked
    slots −inf) and α = exp(logit − rowmax)/rowsum is computed in-register —
    no interstitial normalize pass.  Single-head stats are ``(n_blocks, R)``;
    multi-head ``(H·n_blocks, R)`` (the fused SDDMM's native layout).

    ``scale``/``bias``/``activation`` enable the fused **epilogue**
    (single-head only): per-row scale (flat, ≤ n_rows), per-feature bias
    (flat, ≤ dim), then activation, applied inside the kernel on the last
    visit of each output block.

    Multi-head: ``vals`` of shape (H, C, V, K) with ``B`` of shape
    (H, n, d) run all heads in one kernel call over head-tiled steering
    arrays (``PCSR.steering``) and return (H, n_rows, d) — one
    compilation for the whole head batch.
    """
    cfg = pcsr.config
    B = jnp.asarray(B)
    if stats is not None and vals is None:
        # the prologue interprets vals as logits; stored edge weights (and
        # the 0-valued coverage chunks) are NOT logits — exp(0 − stat)
        # would silently turn padding into weight
        raise ValueError("stats= requires explicit logits as vals "
                         "(from sddmm_softmax_stats), not the stored "
                         "PCSR values")
    fill = -jnp.inf if stats is not None else 0.0
    rowmax, rowsum = stats if stats is not None else (None, None)
    if B.ndim == 3:                       # (H, n, d) head batch
        if scale is not None or bias is not None or activation != "none":
            raise NotImplementedError("epilogue fusion is single-head")
        H = B.shape[0]
        t = pcsr.steering(H, covered=True)
        C_cov = t["trow"].shape[0] // H
        if vals is None:                  # stored values, same for each head
            vals = t["vals"].reshape(H, C_cov, cfg.V, pcsr.K)
        else:
            vals = jnp.asarray(vals)
            if vals.ndim != 4 or vals.shape[0] != H:
                raise ValueError(f"multi-head vals must be (H={H}, C, V, K), "
                                 f"got {vals.shape}")
            vals = _pad_chunk_vals(vals, C_cov - vals.shape[1], fill)
        return _call_heads(t["colidx"], t["lrow"], t["trow"], t["init"],
                           t["fini"], vals, B, rowmax, rowsum, H=H,
                           n_blocks=pcsr.n_blocks, R=cfg.R, V=cfg.V,
                           K=pcsr.K, dblk=cfg.dblk, n_rows=pcsr.n_rows,
                           dim=B.shape[2], interpret=interpret)
    t = pcsr.steering(covered=True)
    C_cov = t["trow"].shape[0]
    if vals is None:
        vals = t["vals"]
    else:
        vals = _pad_chunk_vals(jnp.asarray(vals),
                               C_cov - jnp.shape(vals)[-3], fill)
    return _call(t["colidx"], t["lrow"], t["trow"], t["init"], t["fini"],
                 vals, B, rowmax, rowsum,
                 None if scale is None else jnp.asarray(scale),
                 None if bias is None else jnp.asarray(bias),
                 n_blocks=pcsr.n_blocks, R=cfg.R, V=cfg.V, K=pcsr.K,
                 dblk=cfg.dblk, n_rows=pcsr.n_rows, dim=B.shape[1],
                 activation=activation, interpret=interpret)
