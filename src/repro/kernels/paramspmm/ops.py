"""Jit'd wrapper around the ParamSpMM Pallas kernel: padding, dispatch,
and the high-level ``paramspmm(pcsr, B)`` entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pcsr import PCSR, LANES
from .kernel import paramspmm_kernel


def _pad_cols(B, dblk: int):
    dim = B.shape[1]
    dim_pad = -(-dim // dblk) * dblk
    if dim_pad != dim:
        B = jnp.pad(B, ((0, 0), (0, dim_pad - dim)))
    return B, dim_pad


@functools.partial(jax.jit, static_argnames=(
    "n_blocks", "R", "V", "K", "dblk", "n_rows", "dim", "interpret"))
def _call(colidx, lrow, trow, init, vals, B, *, n_blocks, R, V, K, dblk,
          n_rows, dim, interpret):
    B_padded, _ = _pad_cols(B, dblk)
    out = paramspmm_kernel(colidx, lrow, trow, init, vals, B_padded,
                           n_blocks=n_blocks, R=R, V=V, K=K, dblk=dblk,
                           interpret=interpret)
    # blocks with no chunk are never visited by the grid → their output
    # region is uninitialized; those rows of A are empty ⇒ force zero.
    visited = jnp.zeros(n_blocks, bool).at[trow].set(True)
    out = jnp.where(jnp.repeat(visited, R)[:, None], out, 0.0)
    return out[:n_rows, :dim]


def paramspmm(pcsr: PCSR, B, *, interpret: bool = True):
    """C = A·B where A is held as PCSR. Pallas path (interpret on CPU)."""
    return paramspmm_with_vals(pcsr, None, B, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "H", "n_blocks", "R", "V", "K", "dblk", "n_rows", "dim", "interpret"))
def _call_heads(colidx, lrow, trow, init, vals, B, *, H, n_blocks, R, V, K,
                dblk, n_rows, dim, interpret):
    out = _call(colidx, lrow, trow, init,
                vals.reshape((H * vals.shape[1],) + vals.shape[2:]),
                B.reshape(H * B.shape[1], B.shape[2]),
                n_blocks=H * n_blocks, R=R, V=V, K=K, dblk=dblk,
                n_rows=H * n_blocks * R, dim=dim, interpret=interpret)
    return out.reshape(H, n_blocks * R, dim)[:, :n_rows]


def paramspmm_with_vals(pcsr: PCSR, vals, B, *, interpret: bool = True):
    """SpMM over A's *pattern* with per-slot values supplied at call time —
    the aggregation step of attention GNNs, where the PCSR topology is fixed
    but the edge weights (softmaxed SDDMM scores) change every step.
    ``vals=None`` uses the values stored in the PCSR.

    Multi-head: ``vals`` of shape (H, C, V, K) with ``B`` of shape
    (H, n, d) run all heads in one kernel call over head-tiled steering
    arrays (``PCSR.head_tiled``) and return (H, n_rows, d) — one
    compilation for the whole head batch.
    """
    cfg = pcsr.config
    B = jnp.asarray(B)
    if B.ndim == 3:                       # (H, n, d) head batch
        H = B.shape[0]
        t = pcsr.head_tiled(H)
        if vals is None:                  # stored values, same for each head
            vals = t["vals"].reshape(H, pcsr.num_chunks, cfg.V, pcsr.K)
        vals = jnp.asarray(vals)
        if vals.ndim != 4 or vals.shape[0] != H:
            raise ValueError(f"multi-head vals must be (H={H}, C, V, K), "
                             f"got {vals.shape}")
        return _call_heads(t["colidx"], t["lrow"], t["trow"], t["init"],
                           vals, B, H=H, n_blocks=pcsr.n_blocks, R=cfg.R,
                           V=cfg.V, K=pcsr.K, dblk=cfg.dblk,
                           n_rows=pcsr.n_rows, dim=B.shape[2],
                           interpret=interpret)
    arrs = pcsr.to_jax()
    return _call(arrs["colidx"], arrs["lrow"], arrs["trow"], arrs["init"],
                 arrs["vals"] if vals is None else jnp.asarray(vals),
                 B,
                 n_blocks=pcsr.n_blocks, R=cfg.R, V=cfg.V, K=pcsr.K,
                 dblk=cfg.dblk, n_rows=pcsr.n_rows, dim=B.shape[1],
                 interpret=interpret)
