from .manager import CheckpointManager
