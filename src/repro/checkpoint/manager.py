"""Fault-tolerant checkpointing: atomic publish, async writer, retention,
restart-from-latest, and elastic re-sharding on restore.

Layout:  <dir>/step_<N>/arrays.npz + tree.pkl, plus <dir>/LATEST written
last (atomic rename), so a crash mid-save can never corrupt the restore
path — the previous LATEST stays valid.  Restore re-places arrays with
``jax.device_put`` under the *current* mesh's shardings, so a job restarted
on a different pod count re-shards transparently (elastic scaling).
"""
from __future__ import annotations

import os
import pickle
import shutil
import threading
import time

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot to host memory synchronously; write to disk async."""
        flat, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in flat]          # device→host copy now
        if self.async_save and not blocking:
            self.wait()                                # one writer at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, treedef)

    def _write(self, step: int, host, treedef):
        tmp = os.path.join(self.directory, f".tmp_step_{step}")
        final = os.path.join(self.directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        # npz can't represent ml_dtypes (bfloat16) — store a uint16 view
        # plus the dtype list for the restore-side view-back.
        dtypes = [str(a.dtype) for a in host]
        stored = [a.view(np.uint16) if a.dtype.name == "bfloat16" else a
                  for a in host]
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(stored)})
        with open(os.path.join(tmp, "tree.pkl"), "wb") as f:
            pickle.dump((treedef, dtypes), f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                          # atomic publish
        latest_tmp = os.path.join(self.directory, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.rename(latest_tmp, os.path.join(self.directory, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                try:
                    out.append(int(d.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self):
        path = os.path.join(self.directory, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.directory, f"step_{s}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Load a checkpoint; optionally re-place onto current-mesh
        shardings (elastic restore)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "tree.pkl"), "rb") as f:
            treedef, dtypes = pickle.load(f)
        z = np.load(os.path.join(d, "arrays.npz"))
        import ml_dtypes
        flat = []
        for i in range(len(z.files)):
            a = z[f"a{i}"]
            if dtypes[i] == "bfloat16":
                a = a.view(ml_dtypes.bfloat16)
            flat.append(a)
        tree = jax.tree.unflatten(treedef, flat)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return step, tree
