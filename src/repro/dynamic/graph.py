"""``DynamicGraph`` — the operator-facing dynamic-graph surface.

Owns a :class:`~repro.dynamic.pcsr.DynamicPCSR`, a
:class:`~repro.dynamic.governor.RepackGovernor`, and the jitted operator
closures built over the current layout view.  Every mutation batch runs
the governor; with ``auto_heal=True`` (the default) its verdict is acted
on immediately — ``reselect`` swaps the F tile on the live arrays,
``repack`` rebuilds the steering pack under a fresh config pick — so a
caller streaming edges never has to schedule maintenance itself, yet
every SpMM/GAT call stays exact (the view always encodes the live edge
set; only the layout's *speed* was ever at stake).

Operator closures (engine and Pallas alike) capture steering arrays and
masks at build time, so they are rebuilt lazily whenever
``DynamicPCSR.version`` moves — the price of a mutation batch is one
re-trace on the next call, not a stale result.
"""
from __future__ import annotations

from typing import Optional

from repro.core import CostModel, CSRMatrix, SpMMConfig, config_space
from repro.core.engine import make_gat_message_fn, make_spmm_fn
from repro.obs import trace as _obs_trace

from .governor import GovernorDecision, RepackGovernor
from .pcsr import DynamicPCSR, MutationReport


class DynamicGraph:
    """A mutable graph with always-exact, self-healing SpMM/GAT.

    ``backend`` is ``"engine"`` (pure JAX) or ``"pallas"``;
    ``auto_heal=False`` keeps the governor advisory-only (its decisions
    still append to ``self.decisions``) so a caller can batch re-packs
    at its own cadence via ``repack()``.
    """

    def __init__(self, csr: CSRMatrix, dim: int, *,
                 config: Optional[SpMMConfig] = None,
                 backend: str = "engine", interpret: bool = True,
                 heads: int = 1, space=None, calibration=None,
                 slack: float = 1.25, amortize_steps: int = 100,
                 drift_threshold=None, auto_heal: bool = True):
        self.dim = dim
        self.backend = backend
        self.interpret = interpret
        self.heads = heads
        self.space = space or config_space(dim)
        self.calibration = calibration
        if config is None:
            config, _ = CostModel(csr, calibration=calibration).best(
                dim, self.space, H=heads)
        self.dyn = DynamicPCSR.from_csr(csr, config)
        self.governor = RepackGovernor(
            dim, heads=heads, space=self.space, calibration=calibration,
            slack=slack, amortize_steps=amortize_steps,
            drift_threshold=drift_threshold)
        self.governor.rebaseline(self.dyn, config)
        self.auto_heal = auto_heal
        self.decisions: list[GovernorDecision] = []
        self._fn_version = -1
        self._spmm_fn = None
        self._gat_fns: dict = {}

    @property
    def config(self) -> SpMMConfig:
        return self.dyn.config

    @property
    def version(self) -> int:
        return self.dyn.version

    # -------------------------------------------------------- mutation
    def insert_edges(self, rows, cols, values
                     ) -> tuple[MutationReport, GovernorDecision]:
        rep = self.dyn.insert_edges(rows, cols, values)
        return rep, self._govern()

    def delete_edges(self, rows, cols
                     ) -> tuple[MutationReport, GovernorDecision]:
        rep = self.dyn.delete_edges(rows, cols)
        return rep, self._govern()

    def _govern(self) -> GovernorDecision:
        dec = self.governor.evaluate(self.dyn, self.config)
        if self.auto_heal:
            if dec.action == "repack":
                self.repack(dec.config)
            elif dec.action == "reselect":
                self.dyn.reselect(dec.config)
        self.decisions.append(dec)
        return dec

    def repack(self, config: Optional[SpMMConfig] = None) -> SpMMConfig:
        """Full re-pack of the live edge set; ``config=None`` re-runs the
        config pick (decider re-pick) on the mutated graph."""
        if config is None:
            config, _ = CostModel(self.dyn.to_csr(),
                                  calibration=self.calibration).best(
                self.dim, self.space, H=self.heads)
        with _obs_trace.span("dynamic.repack",
                             config=str(config.astuple()),
                             nnz=int(self.dyn.nnz)):
            self.dyn.repack(config)
        self.governor.rebaseline(self.dyn, config)
        return config

    # -------------------------------------------------------- operators
    def _refresh(self) -> None:
        if self._fn_version != self.dyn.version:
            self._spmm_fn = None
            self._gat_fns = {}
            self._fn_version = self.dyn.version

    def spmm(self, B):
        """C = A·B over the live (possibly degraded) layout — exact."""
        self._refresh()
        if self._spmm_fn is None:
            self._spmm_fn = make_spmm_fn(self.dyn.pcsr,
                                         backend=self.backend,
                                         interpret=self.interpret)
        return self._spmm_fn(B)

    def gat(self, Q, K_mat, Vf, *, slope: float = 0.2):
        """Fused GAT message over the live layout — exact (tombstoned
        slots are masked, delta-chunk padding carries −inf logits)."""
        self._refresh()
        if slope not in self._gat_fns:
            self._gat_fns[slope] = make_gat_message_fn(
                self.dyn.pcsr, backend=self.backend,
                interpret=self.interpret, slope=slope)
        return self._gat_fns[slope](Q, K_mat, Vf)
