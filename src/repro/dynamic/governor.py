"""The re-pack governor: when does a degraded layout stop being worth it?

After every mutation batch the governor recomputes cheap incremental
stats from the :class:`~repro.dynamic.pcsr.DynamicPCSR` (live chunk
count, visited blocks, slot fill — all O(C) or cached, never a fresh
feature extraction) and prices three futures:

* **none** — keep running the degraded steering arrays.  Priced by
  ``degraded_kernel_cost`` over the *live* grid extents (the chunks that
  actually execute, delta chunks and tombstoned slack included).
* **reselect** — re-pick the config *on the existing layout*.  ``F``
  does not participate in packing (it only tiles the feature dim), so
  the governor may re-choose it freely without touching a single
  steering array; V/W/S/B changes would need a re-pack and are not
  offered here.
* **repack** — full ``build_pcsr`` from the live edge set with a fresh
  decider/cost-model config pick.  Charged ``pack_setup_seconds(nnz) /
  amortize_steps`` on top of the fresh layout's priced step time, so a
  re-pack only fires when the degradation pays it back within the
  amortization horizon.

Drift feeds in through :func:`repro.obs.check_drift` against the
snapshot recorded at the last (re-)pack — with the per-feature
thresholds of ``resolve_drift_thresholds`` — and every verdict is
pushed into the decision log (``source="governor"``) plus the
``governor_decisions_total{action=...}`` counter when tracing.

The bounded-staleness guarantee this enforces: results are exact at
every moment (the layout always encodes the live edge set); the
*priced* execution time of the degraded layout never exceeds
``slack ×`` the best fresh layout's time plus the amortized re-pack
cost, because crossing that line triggers ``action="repack"``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core import CostModel, SpMMConfig, config_space
from repro.core.cost_model import degraded_kernel_cost, pack_setup_seconds
from repro.obs import metrics as _obs_metrics, trace as _obs_trace
from repro.obs.decisions import (DecisionRecord, DriftAdvisory, check_drift,
                                 graph_snapshot, record_decision)

from .pcsr import DynamicPCSR


@dataclass
class GovernorDecision:
    """One governor verdict after a mutation batch."""

    action: str                       # "none" | "reselect" | "repack"
    reason: str
    config: SpMMConfig                # config after the action
    degraded_seconds: float           # priced step time, live layout
    fresh_seconds: float              # priced step time, best fresh pack
    repack_amortized_seconds: float   # pack_setup / amortize_steps
    advisory: Optional[DriftAdvisory] = None


class RepackGovernor:
    """Drives do-nothing / re-select / re-pack for one ``DynamicPCSR``.

    ``slack`` is the tolerated priced degradation factor (1.25 → the
    degraded layout may run up to 25% slower than the amortized fresh
    alternative before a re-pack fires); ``amortize_steps`` is the
    number of SpMM steps a re-pack's host cost is spread over;
    ``drift_threshold`` forwards to ``check_drift`` (scalar, per-feature
    dict, or None for the ``$REPRO_DRIFT_THRESHOLD`` env hook).
    """

    def __init__(self, dim: int, *, op: str = "spmm", heads: int = 1,
                 space=None, calibration=None, slack: float = 1.25,
                 amortize_steps: int = 100, drift_threshold=None):
        self.dim = dim
        self.op = op
        self.heads = heads
        self.space = space
        self.calibration = calibration
        self.slack = float(slack)
        self.amortize_steps = int(amortize_steps)
        self.drift_threshold = drift_threshold
        self._baseline: Optional[DecisionRecord] = None

    # ------------------------------------------------------------ pricing
    def _price_degraded(self, dyn: DynamicPCSR,
                        config: SpMMConfig) -> float:
        """Priced seconds of the live degraded grid under ``config`` —
        C/K come from storage, not from a hypothetical fresh pack."""
        bd = degraded_kernel_cost(
            self.dim, config, C=dyn.num_chunks, K=dyn.K,
            n_blocks_visited=dyn.n_visited_blocks, heads=self.heads)
        if self.calibration is None:
            return bd.total
        return self.calibration.price(bd, "spmm")

    def _amortized_repack(self, dyn: DynamicPCSR) -> float:
        return pack_setup_seconds(dyn.nnz) / max(1, self.amortize_steps)

    def rebaseline(self, dyn: DynamicPCSR, config: SpMMConfig) -> None:
        """Record the layout's feature snapshot + priced time — called at
        construction and after every re-pack, so drift is always measured
        against the graph the *current* layout was packed for."""
        csr = dyn.to_csr()
        self._baseline = DecisionRecord(
            source="governor", op=self.op, dim=self.dim, heads=self.heads,
            chosen=config.astuple(),
            predicted_seconds=self._price_degraded(dyn, config),
            topk=[], snapshot=graph_snapshot(csr), calibration=None)

    # ----------------------------------------------------------- verdicts
    def evaluate(self, dyn: DynamicPCSR,
                 config: SpMMConfig) -> GovernorDecision:
        """Price the degraded layout against a fresh pack and decide."""
        if self._baseline is None:
            self.rebaseline(dyn, config)
        t_deg = self._price_degraded(dyn, config)
        amort = self._amortized_repack(dyn)
        csr = dyn.to_csr()
        advisory = check_drift(csr, record=self._baseline,
                               threshold=self.drift_threshold)
        # fast path: no drift and the degraded price is still within
        # slack of the baseline price — skip the full config sweep
        base_t = self._baseline.predicted_seconds or t_deg
        if advisory is None and t_deg <= self.slack * base_t:
            return self._record(GovernorDecision(
                "none", "no drift; degraded price within slack of the "
                "packed baseline", config, t_deg, base_t, amort))
        space = self.space or config_space(self.dim)
        model = CostModel(csr, calibration=self.calibration)
        best_cfg, t_fresh = model.best(self.dim, space, op=self.op,
                                       H=self.heads)
        if t_deg > self.slack * (t_fresh + amort):
            return self._record(GovernorDecision(
                "repack",
                f"degraded layout priced {t_deg / max(t_fresh, 1e-30):.2f}×"
                f" the best fresh pack (+ amortized re-pack cost)",
                best_cfg, t_deg, t_fresh, amort, advisory))
        # still worth keeping the layout — but the feature-dim tiling F
        # (and only F) can be re-picked without re-packing
        f_space = {c.F for c in space if (c.V, c.W, c.S, c.B) ==
                   (config.V, config.W, config.S, config.B)}
        best_f, t_best_f = config, t_deg
        for f in sorted(f_space):
            cand = config.replace(F=f)
            t = self._price_degraded(dyn, cand)
            if t < t_best_f:
                best_f, t_best_f = cand, t
        if best_f != config:
            return self._record(GovernorDecision(
                "reselect",
                f"F={best_f.F} prices {t_best_f / max(t_deg, 1e-30):.2f}× "
                f"the current F={config.F} on the same steering arrays",
                best_f, t_best_f, t_fresh, amort, advisory))
        reason = ("drift advisory fired but the degraded layout still "
                  "prices within slack" if advisory is not None else
                  "degraded price within slack of the best fresh pack")
        return self._record(GovernorDecision(
            "none", reason, config, t_deg, t_fresh, amort, advisory))

    def _record(self, dec: GovernorDecision) -> GovernorDecision:
        _obs_metrics.counter("governor_decisions_total").inc(
            action=dec.action)
        if _obs_trace.trace_enabled():
            record_decision(
                source="governor", op=self.op, dim=self.dim,
                heads=self.heads, chosen=dec.config,
                predicted_seconds=dec.degraded_seconds,
                snapshot={"action": dec.action,
                          "degraded_seconds": dec.degraded_seconds,
                          "fresh_seconds": dec.fresh_seconds,
                          "repack_amortized_seconds":
                              dec.repack_amortized_seconds,
                          "drifted": sorted(dec.advisory.drifted)
                          if dec.advisory else []})
            _obs_trace.instant("governor_decision", cat="decision",
                               action=dec.action, reason=dec.reason)
        return dec
