"""Dynamic-graph robustness layer: incremental PCSR maintenance with
drift-actuated self-healing re-pack/re-selection.

Production graphs mutate under traffic; the steering arrays and the
decider's ⟨W,F,V,S,B⟩ pick were chosen for a graph that no longer
exists.  This package keeps SpMM/SDDMM/GAT **exact at every moment**
while letting layout quality degrade only within priced bounds:

* :class:`DynamicPCSR` — batched edge insert/delete without a full
  re-pack (slack slots → delta chunks → tombstones; steering arrays
  only, the kernels are untouched);
* :class:`RepackGovernor` — prices the degraded layout against a fresh
  pack + amortized ``pack_setup_seconds`` and consults ``check_drift``
  to decide do-nothing / re-select F / full re-pack with config re-pick;
* :class:`DynamicGraph` — the operator surface: mutate, auto-heal, and
  keep calling ``spmm``/``gat``;
* :func:`refresh_dist_graph` — the distributed path: per-shard drift
  detection with per-shard re-pack (only changed shards rebuild).

See docs/DYNAMIC.md for the layout, the governor decision table, and
the bounded-staleness guarantee.
"""
from .dist import ShardRefreshReport, refresh_dist_graph, shard_drift
from .governor import GovernorDecision, RepackGovernor
from .graph import DynamicGraph
from .pcsr import DynamicPCSR, MutationReport

__all__ = [
    "DynamicPCSR", "MutationReport",
    "RepackGovernor", "GovernorDecision",
    "DynamicGraph",
    "refresh_dist_graph", "shard_drift", "ShardRefreshReport",
]
