"""Per-shard drift detection + selective re-pack for ``DistGraph``.

The distributed analogue of the single-device governor: a mutated
global adjacency is re-sliced under the **same** partition boundaries
(``partition_csr(..., starts=part.starts)``) and the same padded
shapes (``halo_pad_min``), so

* shards whose local edge set did not change come out bit-identical and
  **reuse their existing PCSR objects** (steering caches, device copies
  and all — asserted by identity in the tests);
* shards whose edges changed re-pack *locally*: their steering pack is
  rebuilt, and when the shard's feature snapshot drifted past the
  per-feature thresholds its config is re-picked via ``CostModel.best``
  on the new local CSR — the per-shard form of decider re-selection;
* the halo exchange plan is recomputed (cheap host numpy) and the lazy
  jitted SPMD closures are invalidated so they rebuild on next call.
  The SPMD program *structure* — one ``shard_map`` over the same mesh,
  same padded shapes — is untouched unless a mutated halo outgrows the
  old ``halo_pad``, in which case every shard's extended column space
  widens and all shards rebuild (reported as ``halo_pad_grew``).

Entry point: ``refresh_dist_graph(g, new_csr)`` (also exposed as
``DistGraph.refresh``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import CostModel, CSRMatrix, build_pcsr, config_space
from repro.obs import metrics as _obs_metrics, trace as _obs_trace
from repro.obs.decisions import (DecisionRecord, DriftAdvisory, check_drift,
                                 graph_snapshot)


def _same_shard_csr(a: CSRMatrix, b: CSRMatrix) -> bool:
    return (a.nnz == b.nnz and a.n_cols == b.n_cols
            and np.array_equal(a.indptr, b.indptr)
            and np.array_equal(a.indices, b.indices)
            and np.array_equal(a.data, b.data))


def shard_drift(g, new_csr: CSRMatrix, *, threshold=None
                ) -> dict[int, Optional[DriftAdvisory]]:
    """Per-shard drift check of a mutated global CSR against the local
    subgraphs ``g`` packed: re-slices ``new_csr`` under ``g``'s own
    boundaries and compares each *changed* shard's snapshot.  Returns
    ``{shard: advisory_or_None}`` for the changed shards only (an entry
    with ``None`` changed without crossing any threshold)."""
    from repro.dist.partition import partition_csr

    part = g.part
    new_part = partition_csr(new_csr, part.n_parts, part.strategy,
                             starts=part.starts,
                             halo_pad_min=part.halo_pad)
    out: dict[int, Optional[DriftAdvisory]] = {}
    for p in range(part.n_parts):
        old_s, new_s = part.shards[p], new_part.shards[p]
        if _same_shard_csr(old_s.csr, new_s.csr):
            continue
        rec = DecisionRecord(
            source="dist_shard", op="spmm", dim=g.dim, heads=g.heads,
            chosen=g.configs[p].astuple(), predicted_seconds=None,
            topk=[], snapshot=graph_snapshot(old_s.csr), calibration=None)
        out[p] = check_drift(new_s.csr, record=rec, threshold=threshold)
    return out


@dataclass
class ShardRefreshReport:
    """What one ``refresh_dist_graph`` pass rebuilt."""

    changed: list = field(default_factory=list)    # shards with new edges
    repicked: list = field(default_factory=list)   # drifted → new config
    reused: list = field(default_factory=list)     # PCSR object kept as-is
    advisories: dict = field(default_factory=dict)  # shard -> DriftAdvisory
    halo_pad_grew: bool = False


def refresh_dist_graph(g, new_csr: CSRMatrix, *, threshold=None,
                       max_f: int = 4) -> ShardRefreshReport:
    """Swap a mutated adjacency into a live ``DistGraph`` by re-packing
    only the shards whose local subgraph actually changed.

    Shards with unchanged edges keep their ``Shard`` and ``PCSR``
    objects (identity-preserved); changed shards rebuild their local
    pack under their existing config, or a freshly ``CostModel.best``-
    picked one when their feature snapshot drifted past ``threshold``
    (per-feature dict / scalar / ``$REPRO_DRIFT_THRESHOLD``).  Halo maps
    are recomputed and the lazy jitted closures dropped; the partition
    boundaries, mesh, and padded shapes survive unless ``halo_pad``
    outgrows its old value (then every shard rebuilds — reported).
    """
    import jax.numpy as jnp

    from repro.dist.halo import build_halo
    from repro.dist.packing import pack_shards
    from repro.dist.partition import partition_csr, split_local_halo

    if new_csr.n_rows != g.part.n_global:
        raise ValueError("refresh mutates edges over a fixed node set — "
                         f"got {new_csr.n_rows} rows for a "
                         f"{g.part.n_global}-row partition")
    old_part = g.part
    P = old_part.n_parts
    rep = ShardRefreshReport()
    with _obs_trace.span("dynamic.shard_repack", n_parts=P):
        new_part = partition_csr(new_csr, P, old_part.strategy,
                                 starts=old_part.starts,
                                 halo_pad_min=old_part.halo_pad)
        rep.halo_pad_grew = new_part.halo_pad > old_part.halo_pad
        fwd_pcsrs = list(g._fwd.pcsrs)
        configs = list(g.configs)
        space = config_space(g.dim, max_f)
        for p in range(P):
            old_s, new_s = old_part.shards[p], new_part.shards[p]
            if not rep.halo_pad_grew and _same_shard_csr(old_s.csr,
                                                         new_s.csr):
                new_part.shards[p] = old_s       # identity-preserving
                rep.reused.append(p)
                continue
            rep.changed.append(p)
            rec = DecisionRecord(
                source="dist_shard", op="spmm", dim=g.dim, heads=g.heads,
                chosen=configs[p].astuple(), predicted_seconds=None,
                topk=[], snapshot=graph_snapshot(old_s.csr),
                calibration=None)
            adv = check_drift(new_s.csr, record=rec, threshold=threshold)
            if adv is not None:
                rep.advisories[p] = adv
                configs[p], _ = CostModel(
                    new_s.csr, calibration=g.calibration).best(
                    g.dim, space, H=g.heads)
                rep.repicked.append(p)
            s = new_s.csr
            fwd_pcsrs[p] = build_pcsr(s.indptr, s.indices, s.data,
                                      s.n_rows, s.n_cols, configs[p])
            _obs_metrics.counter("dist_shard_repacks_total").inc(
                shard=p, repicked=adv is not None)
        g.part = new_part
        g.csr = new_csr
        g.configs = configs
        g.halo = build_halo(new_part)
        g._fwd = pack_shards(fwd_pcsrs)
        g._send_idx = jnp.asarray(g.halo.send_idx)
        g._halo_src = jnp.asarray(g.halo.halo_src)
        if g.overlap:
            loc_pcsrs = list(g._loc.pcsrs)
            halo_pcsrs = list(g._halo_pack.pcsrs)
            for p in rep.changed:
                loc, hal = split_local_halo(new_part.shards[p], new_part)
                g._split_csrs[p] = (loc, hal)
                lc, hc = g.overlap_configs[p]
                if p in rep.repicked:
                    lc, _ = CostModel(loc, calibration=g.calibration).best(
                        g.dim, space, H=g.heads)
                    hc, _ = CostModel(hal, calibration=g.calibration).best(
                        g.dim, space, H=g.heads)
                    g.overlap_configs[p] = (lc, hc)
                loc_pcsrs[p] = build_pcsr(loc.indptr, loc.indices, loc.data,
                                          loc.n_rows, loc.n_cols, lc)
                halo_pcsrs[p] = build_pcsr(hal.indptr, hal.indices, hal.data,
                                           hal.n_rows, hal.n_cols, hc)
            g._loc = pack_shards(loc_pcsrs)
            g._halo_pack = pack_shards(halo_pcsrs)
        # drop every lazy jitted/packed cache — they close over the old
        # steering arrays and shapes; rebuilt on next call
        g._bwd_pack = None
        g._bwd_split_pack = None
        g._spmm_fn = None
        g._gat_fns = {}
        g._gat_packs = {}
        g._fused_fns = {}
        g._fused_bwd_fns = {}
        g._bwd_fn = None
    return rep
