"""Incremental PCSR maintenance: exact SpMM under edge mutation without
full re-packs.

``DynamicPCSR`` wraps a packed :class:`repro.core.pcsr.PCSR` and absorbs
batched edge inserts/deletes by editing *steering arrays only* — the
same trick the balanced ``B=True`` schedule used to change the layout
without touching the kernel:

* **slack slots** — an insert first lands in a padding slot of a chunk
  already targeting its output block (the packed layout always carries
  some: capacity roundup, V-padding, and previously tombstoned slots all
  leave ``vals == 0`` holes the kernel multiplies by zero);
* **delta chunks** — when a block has no free slot left, a fresh
  all-padding chunk targeting that block is appended to storage.  The
  kernel's chunk walk is unchanged: one more ``trow`` entry, one more
  ``(V, K)`` vals tile — empty-block *birth* is just a delta chunk for a
  block nothing targeted before;
* **tombstones** — a delete zeroes the edge's value cell.  A vector
  whose cells are all zero contributes exactly nothing in every path
  (the SpMM multiplies by 0, the SDDMM masks ``vals != 0``, the GAT
  prologue carries −inf logits on padding), so deletes are free at
  kernel time and the slot returns to the block's free list.

Storage is **append-ordered**; the kernel needs each block's chunks
*contiguous* (the ``fini`` epilogue steering and the VMEM-revisit
accumulation both key off grouped ``trow``), so the kernel-facing view
is materialized lazily through a grouping permutation — chunks sorted by
the first storage position of their block, stable within a block.  That
preserves the base pack's emit order (ascending or LPT) and appends new
blocks' groups at the tail: O(C log C) on the chunk count per refresh,
never O(nnz log nnz) on the edge set.

Results stay **exact at every moment** — the live arrays always encode
precisely the mutated edge set; only layout *quality* degrades (padding
slots accumulate, delta chunks lengthen the grid) until the governor
(:mod:`repro.dynamic.governor`) prices a re-pack.

Exactly-zero edge values are not representable (a zero cell *is* a
padding slot — the same convention ``pcsr_to_coo`` already applies), so
``insert_edges`` rejects them.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.pcsr import PCSR, SpMMConfig, build_pcsr, pcsr_slot_coords
from repro.core.sparse import CSRMatrix
from repro.obs import metrics as _obs_metrics


@dataclass
class MutationReport:
    """Where one batch of edge mutations landed."""

    inserted: int = 0          # new edges added
    updated: int = 0           # existing edges whose value changed
    deleted: int = 0           # edges removed
    slack_inserts: int = 0     # inserts absorbed by existing slots
    delta_chunks: int = 0      # fresh chunks appended for overflow
    tombstones: int = 0        # vectors fully zeroed by deletes
    missing: int = 0           # deletes of edges that did not exist


class DynamicPCSR:
    """A PCSR that tolerates edge insert/delete batches in place.

    Construct from a packed ``PCSR`` (or ``DynamicPCSR.from_csr``), call
    ``insert_edges`` / ``delete_edges``, and read ``.pcsr`` — a normal
    :class:`~repro.core.pcsr.PCSR` every kernel/engine path consumes
    unchanged.  ``version`` bumps on every effective mutation so callers
    holding jitted closures know when to rebuild.
    """

    def __init__(self, base: PCSR):
        cfg = base.config
        self.config: SpMMConfig = cfg
        self.n_rows, self.n_cols = base.n_rows, base.n_cols
        self.n_blocks, self.K = base.n_blocks, base.K
        self.V, self.W, self.R = cfg.V, cfg.W, cfg.R
        # storage, append-ordered: (C_s, K) steering + (C_s, V, K) vals
        self._colidx = base.colidx.reshape(-1, base.K).copy()
        self._lrow = base.lrow.reshape(-1, base.K).copy()
        self._trow = base.trow.astype(np.int64).copy()
        self._vals = base.vals.copy()
        # edge bookkeeping: vector map (panel, col) -> (chunk, slot) and
        # per-block free-slot lists (padding + tombstoned slots)
        self._vec: dict[tuple[int, int], tuple[int, int]] = {}
        self._free: dict[int, list[tuple[int, int]]] = {}
        rows, cols, flat = pcsr_slot_coords(base)
        c = flat // (self.V * base.K)
        k = flat % base.K
        occ = np.zeros((self._trow.shape[0], base.K), bool)
        occ[c, k] = True
        panels = rows // self.V
        for p, col, ci, ki in zip(panels.tolist(), cols.tolist(),
                                  c.tolist(), k.tolist()):
            self._vec[(p, col)] = (ci, ki)
        free_c, free_k = np.nonzero(~occ)
        for ci, ki in zip(free_c.tolist(), free_k.tolist()):
            self._free.setdefault(int(self._trow[ci]), []).append((ci, ki))
        self.nnz = base.nnz
        self.nnz_vec = len(self._vec)
        self.base_num_chunks = base.num_chunks
        self.version = 0
        self.n_slack_inserts = 0
        self.n_delta_chunks = 0
        self.n_tombstones = 0
        self._view: PCSR | None = None

    @classmethod
    def from_csr(cls, csr: CSRMatrix, config: SpMMConfig) -> "DynamicPCSR":
        return cls(build_pcsr(csr.indptr, csr.indices, csr.data,
                              csr.n_rows, csr.n_cols, config))

    # ------------------------------------------------------------ stats
    @property
    def num_chunks(self) -> int:
        return int(self._trow.shape[0])

    @property
    def num_slots(self) -> int:
        return self.num_chunks * self.K

    @property
    def n_visited_blocks(self) -> int:
        """Distinct blocks the live chunks target (bounds output traffic
        in the degraded grid — includes fully-tombstoned blocks)."""
        return len(np.unique(self._trow))

    @property
    def n_nonempty_blocks(self) -> int:
        """Blocks holding at least one live vector."""
        return len({int(self._trow[c]) for c, _ in self._vec.values()})

    @property
    def padding_ratio(self) -> float:
        """PR_V over the live edge set (paper Eq. 2)."""
        if self.nnz_vec == 0:
            return 0.0
        return 1.0 - self.nnz / (self.nnz_vec * self.V)

    @property
    def slot_fill(self) -> float:
        """Fraction of storage slots holding a live vector — the number
        the governor watches decay as tombstones and delta-chunk padding
        accumulate."""
        return self.nnz_vec / max(1, self.num_slots)

    # ------------------------------------------------------- mutations
    def _panel_of(self, row: int) -> tuple[int, int, int]:
        panel = row // self.V
        return panel, row - panel * self.V, panel // self.W

    def _claim_slot(self, block: int) -> tuple[int, int]:
        """A free slot in a chunk targeting ``block`` — reusing slack
        first, appending a delta chunk only when the block is full."""
        free = self._free.get(block)
        if free:
            self.n_slack_inserts += 1
            _obs_metrics.counter("dynamic_slack_inserts_total").inc()
            return free.pop()
        c = self.num_chunks
        self._colidx = np.concatenate(
            [self._colidx, np.zeros((1, self.K), np.int32)])
        self._lrow = np.concatenate(
            [self._lrow, np.zeros((1, self.K), np.int32)])
        self._trow = np.concatenate(
            [self._trow, np.asarray([block], np.int64)])
        self._vals = np.concatenate(
            [self._vals, np.zeros((1, self.V, self.K), np.float32)])
        self._free[block] = [(c, k) for k in range(self.K - 1, 0, -1)]
        self.n_delta_chunks += 1
        _obs_metrics.counter("dynamic_delta_chunks_total").inc()
        return c, 0

    def insert_edges(self, rows, cols, values) -> MutationReport:
        """Insert (or update) a batch of edges.  Exact immediately: the
        next ``.pcsr`` view encodes the new edge set bit-for-bit."""
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        values = np.asarray(values, np.float32)
        if rows.shape != cols.shape or rows.shape != values.shape:
            raise ValueError("rows/cols/values must match in length")
        if (values == 0).any():
            raise ValueError("cannot insert an edge with value exactly 0 "
                             "(a zero cell is a padding slot)")
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_rows
                          or cols.min() < 0 or cols.max() >= self.n_cols):
            raise ValueError("edge endpoints out of range — the dynamic "
                             "layer mutates edges over a fixed node set")
        rep = MutationReport()
        slack0, delta0 = self.n_slack_inserts, self.n_delta_chunks
        for r, col, val in zip(rows.tolist(), cols.tolist(),
                               values.tolist()):
            panel, v_off, block = self._panel_of(r)
            key = (panel, col)
            loc = self._vec.get(key)
            if loc is None:
                loc = self._claim_slot(block)
                c, k = loc
                self._colidx[c, k] = col
                self._lrow[c, k] = panel - block * self.W
                self._vec[key] = loc
                self.nnz_vec += 1
            c, k = loc
            if self._vals[c, v_off, k] != 0.0:
                rep.updated += 1
            else:
                rep.inserted += 1
                self.nnz += 1
            self._vals[c, v_off, k] = val
        rep.slack_inserts = self.n_slack_inserts - slack0
        rep.delta_chunks = self.n_delta_chunks - delta0
        self._committed(rep, rows.size)
        return rep

    def delete_edges(self, rows, cols) -> MutationReport:
        """Delete a batch of edges by tombstoning their value cells.
        Deleting a non-existent edge is counted, not an error (streams
        replay)."""
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        rep = MutationReport()
        for r, col in zip(rows.tolist(), cols.tolist()):
            panel, v_off, block = self._panel_of(r)
            key = (panel, col)
            loc = self._vec.get(key)
            if loc is None or self._vals[loc[0], v_off, loc[1]] == 0.0:
                rep.missing += 1
                continue
            c, k = loc
            self._vals[c, v_off, k] = 0.0
            rep.deleted += 1
            self.nnz -= 1
            if not self._vals[c, :, k].any():      # whole vector gone
                del self._vec[key]
                self.nnz_vec -= 1
                self.n_tombstones += 1
                rep.tombstones += 1
                _obs_metrics.counter("dynamic_tombstones_total").inc()
                self._free.setdefault(block, []).append((c, k))
        self._committed(rep, rows.size)
        return rep

    def _committed(self, rep: MutationReport, batch: int) -> None:
        if rep.inserted or rep.updated or rep.deleted:
            self.version += 1
            self._view = None
        _obs_metrics.counter("dynamic_mutations_total").inc(
            batch, kind="insert" if rep.deleted == 0 else "delete")

    # ----------------------------------------------------------- views
    @property
    def pcsr(self) -> PCSR:
        """The kernel-facing grouped view (cached until next mutation)."""
        if self._view is None:
            C = self.num_chunks
            first = np.full(self.n_blocks, C, np.int64)
            np.minimum.at(first, self._trow, np.arange(C, dtype=np.int64))
            order = np.lexsort((np.arange(C), first[self._trow]))
            trow = self._trow[order].astype(np.int32)
            init = np.ones(C, np.int32)
            init[1:] = (trow[1:] != trow[:-1]).astype(np.int32)
            self._view = PCSR(
                self.config, self.n_rows, self.n_cols, self.n_blocks,
                self.K, self._colidx[order].reshape(-1).copy(),
                self._lrow[order].reshape(-1).copy(), trow, init,
                self._vals[order].copy(), self.nnz, self.nnz_vec,
                self.n_nonempty_blocks)
        return self._view

    def to_csr(self) -> CSRMatrix:
        """The mutated edge set as a fresh CSR (re-pack / verify path)."""
        if not self._vec:
            return CSRMatrix.from_coo(
                np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32), self.n_rows, self.n_cols)
        locs = np.asarray([(p, col, c, k) for (p, col), (c, k)
                           in self._vec.items()], np.int64)
        vec_vals = self._vals[locs[:, 2], :, locs[:, 3]]      # (nv, V)
        pan, v = np.nonzero(vec_vals)
        rows = locs[pan, 0] * self.V + v
        cols = locs[pan, 1]
        return CSRMatrix.from_coo(rows, cols, vec_vals[pan, v],
                                  self.n_rows, self.n_cols,
                                  sum_duplicates=False)

    def reselect(self, config: SpMMConfig) -> None:
        """Swap the config *without* re-packing.  Only ``F`` (the
        feature-dim tile width) is layout-free; the packing axes
        ⟨V, W, S, B⟩ must match the arrays on disk."""
        if (config.V, config.W, config.S, config.B) != \
                (self.V, self.W, self.config.S, self.config.B):
            raise ValueError(
                f"reselect may only change F: layout is packed for "
                f"{self.config.astuple()}, got {config.astuple()} — "
                f"use repack() for V/W/S/B changes")
        if config != self.config:
            self.config = config
            self.version += 1
            self._view = None

    def repack(self, config: SpMMConfig | None = None) -> PCSR:
        """Full re-pack from the live edge set — resets every slack/
        tombstone/delta-chunk debt (optionally under a new config) and
        re-seats this DynamicPCSR on the fresh layout."""
        csr = self.to_csr()
        fresh = build_pcsr(csr.indptr, csr.indices, csr.data,
                           csr.n_rows, csr.n_cols, config or self.config)
        _obs_metrics.counter("dynamic_repacks_total").inc(
            config=str((config or self.config).astuple()))
        version = self.version
        self.__init__(fresh)
        self.version = version + 1
        return fresh
