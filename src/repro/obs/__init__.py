"""Process-wide telemetry: spans, counters, decision log, drift checks.

One switch gates everything: tracing is off by default and every
instrumentation point in the hot paths degrades to a near-zero no-op.
Enable with ``obs.tracing(path)`` (context manager), the ``--trace``
flags on ``apps/gnn`` / ``benchmarks/run.py`` / ``decider_train``, or
``REPRO_TRACE=path`` in the environment; read the exported Chrome-trace
JSON in Perfetto or with ``python -m repro.apps.obs_report``.

See docs/OBSERVABILITY.md for the span/counter inventory and the
decision-log schema.
"""
from repro.obs.trace import (
    tracing, start_tracing, stop_tracing, trace_enabled,
    span, instant, export_trace, trace_events,
)
from repro.obs.metrics import (
    counter, gauge, histogram,
    metrics_snapshot, reset_metrics, intercept_pallas,
)
from repro.obs.decisions import (
    DecisionRecord, DriftAdvisory, DRIFT_FEATURES, DRIFT_THRESHOLD,
    record_decision, decision_log, clear_decisions,
    graph_snapshot, check_drift, resolve_drift_thresholds,
)
from repro.obs.trace import _env_autostart

__all__ = [
    # trace
    "tracing", "start_tracing", "stop_tracing", "trace_enabled",
    "span", "instant", "export_trace", "trace_events",
    # metrics
    "counter", "gauge", "histogram",
    "metrics_snapshot", "reset_metrics", "intercept_pallas",
    # decisions
    "DecisionRecord", "DriftAdvisory", "DRIFT_FEATURES", "DRIFT_THRESHOLD",
    "record_decision", "decision_log", "clear_decisions",
    "graph_snapshot", "check_drift", "resolve_drift_thresholds",
]

_env_autostart()
del _env_autostart
