"""Labeled counter/gauge/histogram registry for the obs layer.

Instruments are cheap named handles — ``counter("pack_cache_hits_total")``
returns the same object every call — and every mutating method
(``inc``/``set``/``observe``) is a no-op unless a tracing session is
active, so instrumented hot paths cost a dict lookup and a boolean check
when the layer is off.  Label sets distinguish series within one
instrument (``inc(kind="steering")``); ``metrics_snapshot()`` renders
everything into plain JSON-ready dicts keyed ``"k=v,k2=v2"``.

The module also owns the one Pallas launch-count definition:
``intercept_pallas(callback)`` patches ``pl.pallas_call`` so each
dispatch reports ``kw.get("name", "?")`` — trace-time count == launch
count per call.  ``benchmarks.common.count_pallas_calls`` delegates here
and a probe installed for the duration of a tracing session feeds the
``pallas_calls_total{kernel=...}`` counter, so the bench, the fusion
tests, and the trace can never disagree about what counts as a launch.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Callable

from repro.obs import trace as _trace

__all__ = [
    "counter", "gauge", "histogram",
    "metrics_snapshot", "reset_metrics", "intercept_pallas",
]

_LOCK = threading.Lock()
_REGISTRY: dict[str, "_Instrument"] = {}


def _label_key(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


class _Instrument:
    kind = "?"

    def __init__(self, name: str):
        self.name = name
        self._series: dict[str, object] = {}

    def _reset(self):
        self._series = {}


class Counter(_Instrument):
    """Monotonically increasing per-label-set totals."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if not _trace.trace_enabled():
            return
        key = _label_key(labels)
        with _LOCK:
            self._series[key] = self._series.get(key, 0.0) + value


class Gauge(_Instrument):
    """Last-write-wins per-label-set values."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not _trace.trace_enabled():
            return
        with _LOCK:
            self._series[_label_key(labels)] = float(value)


class Histogram(_Instrument):
    """Streaming count/sum/min/max per label set (no buckets — the
    trace spans carry the full distribution when one is needed)."""

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        if not _trace.trace_enabled():
            return
        value = float(value)
        key = _label_key(labels)
        with _LOCK:
            st = self._series.get(key)
            if st is None:
                self._series[key] = {"count": 1, "sum": value,
                                     "min": value, "max": value}
            else:
                st["count"] += 1
                st["sum"] += value
                st["min"] = min(st["min"], value)
                st["max"] = max(st["max"], value)


def _get(name: str, cls) -> _Instrument:
    with _LOCK:
        inst = _REGISTRY.get(name)
        if inst is None:
            inst = _REGISTRY[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{inst.kind}, not {cls.kind}")
        return inst


def counter(name: str) -> Counter:
    """Get-or-create the named counter."""
    return _get(name, Counter)


def gauge(name: str) -> Gauge:
    """Get-or-create the named gauge."""
    return _get(name, Gauge)


def histogram(name: str) -> Histogram:
    """Get-or-create the named histogram."""
    return _get(name, Histogram)


def metrics_snapshot() -> dict:
    """``{metric_name: {"k=v,...": value_or_stats}}`` for every series
    with at least one observation (JSON-ready)."""
    with _LOCK:
        return {name: {k: (dict(v) if isinstance(v, dict) else v)
                       for k, v in inst._series.items()}
                for name, inst in _REGISTRY.items() if inst._series}


def reset_metrics() -> None:
    """Zero every series (instruments stay registered)."""
    with _LOCK:
        for inst in _REGISTRY.values():
            inst._reset()


# ------------------------------------------------- pallas interception
@contextmanager
def intercept_pallas(callback: Callable[[str], None]):
    """Patch ``pl.pallas_call`` for the body; ``callback(kernel_name)``
    fires per dispatch.  THE shared launch-count definition —
    ``count_pallas_calls``, the fusion tests, and the tracing probe all
    route through here."""
    from jax.experimental import pallas as pl
    orig = pl.pallas_call

    def counting(*a, **kw):
        callback(kw.get("name", "?"))
        return orig(*a, **kw)

    pl.pallas_call = counting
    try:
        yield
    finally:
        pl.pallas_call = orig


_PROBE_ORIG = None


def _install_pallas_probe() -> None:
    """Patch ``pl.pallas_call`` for the tracing session: every dispatch
    increments ``pallas_calls_total{kernel=...}`` and drops an instant
    event.  Launches are observed at trace time — a program compiled
    before the session started will not re-trace and thus not count."""
    global _PROBE_ORIG
    if _PROBE_ORIG is not None:
        return
    try:
        from jax.experimental import pallas as pl
    except ImportError:                               # pragma: no cover
        return
    orig = pl.pallas_call

    def probed(*a, **kw):
        name = kw.get("name", "?")
        counter("pallas_calls_total").inc(kernel=name)
        _trace.instant("pallas_call", cat="kernel", kernel=name)
        return orig(*a, **kw)

    _PROBE_ORIG = orig
    pl.pallas_call = probed


def _remove_pallas_probe() -> None:
    global _PROBE_ORIG
    if _PROBE_ORIG is None:
        return
    from jax.experimental import pallas as pl
    pl.pallas_call = _PROBE_ORIG
    _PROBE_ORIG = None
