"""Nestable spans + instant events with Chrome-trace-event JSON export.

One process-wide switch gates the whole ``repro.obs`` layer: tracing is
off by default and every instrumentation point degrades to a handful of
attribute loads (``span`` returns a shared null context manager,
``instant``/counters return immediately).  Enable it with the
``tracing(path)`` context manager, ``start_tracing()``/``stop_tracing()``,
or the ``REPRO_TRACE=path`` environment variable (checked once at import;
the trace is written atexit).

Exported files follow the Chrome trace event format — ``"X"`` complete
events (``ts``/``dur`` in microseconds) nest by containment per thread,
``"i"`` instant events mark points in time, and one ``"C"`` counter
event per metric series is appended at export so Perfetto /
``chrome://tracing`` render the final counter values.  Two extra
top-level keys, ``repro_metrics`` and ``repro_decisions``, carry the
full metric snapshot and the structured decision log (extra keys are
legal in the format and ignored by viewers).
"""
from __future__ import annotations

import json
import os
import threading
from time import perf_counter, time as _walltime
from typing import Any, Optional

__all__ = [
    "tracing", "start_tracing", "stop_tracing", "trace_enabled",
    "span", "instant", "export_trace", "trace_events",
]

_LOCK = threading.Lock()
_STATE: Optional["_TraceState"] = None


class _TraceState:
    __slots__ = ("events", "t0", "path")

    def __init__(self, path=None):
        self.events: list[dict] = []
        self.t0 = perf_counter()
        self.path = path

    def now_us(self) -> float:
        return (perf_counter() - self.t0) * 1e6

    def add(self, event: dict) -> None:
        with _LOCK:
            self.events.append(event)


def trace_enabled() -> bool:
    """True while a tracing session is active (the one switch the whole
    obs layer gates on)."""
    return _STATE is not None


class _Span:
    """Context manager emitting one ``"X"`` complete event on exit."""

    __slots__ = ("_state", "_name", "_cat", "_args", "_ts")

    def __init__(self, state, name, cat, args):
        self._state, self._name, self._cat, self._args = \
            state, name, cat, args

    def __enter__(self):
        self._ts = self._state.now_us()
        return self

    def __exit__(self, *exc):
        st = self._state
        st.add({
            "name": self._name, "cat": self._cat, "ph": "X",
            "ts": self._ts, "dur": st.now_us() - self._ts,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": self._args,
        })
        return False


class _NullSpan:
    """Shared no-op span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "repro", **args: Any):
    """Open a nestable span: ``with span("gnn.step", step=i): ...``.
    Returns a shared null context manager when tracing is disabled."""
    st = _STATE
    if st is None:
        return _NULL_SPAN
    return _Span(st, name, cat, args)


def instant(name: str, cat: str = "repro", **args: Any) -> None:
    """Record a point-in-time ``"i"`` event (no-op when disabled)."""
    st = _STATE
    if st is None:
        return
    st.add({
        "name": name, "cat": cat, "ph": "i", "s": "t",
        "ts": st.now_us(),
        "pid": os.getpid(), "tid": threading.get_ident(),
        "args": args,
    })


def trace_events() -> list[dict]:
    """Snapshot of the event buffer (empty list when disabled)."""
    st = _STATE
    if st is None:
        return []
    with _LOCK:
        return list(st.events)


def _jsonable(obj):
    """json.dump fallback: numpy scalars/arrays, tuples-in-sets, etc."""
    if hasattr(obj, "item"):          # numpy scalar
        return obj.item()
    if hasattr(obj, "tolist"):        # numpy array
        return obj.tolist()
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    return str(obj)


def start_tracing(path: Optional[str] = None) -> None:
    """Open a tracing session: fresh event buffer, metrics registry and
    decision log reset (a trace captures its own window), and the Pallas
    launch probe installed.  Raises if a session is already active."""
    global _STATE
    if _STATE is not None:
        raise RuntimeError("tracing already active")
    from repro.obs import decisions as _decisions, metrics as _metrics
    _STATE = _TraceState(path)
    _metrics.reset_metrics()
    _decisions.clear_decisions()
    _metrics._install_pallas_probe()


def export_trace(path: str) -> str:
    """Write the current buffer + metric snapshot + decision log as
    Chrome-trace JSON without stopping the session.  Returns ``path``."""
    from repro.obs import decisions as _decisions, metrics as _metrics
    st = _STATE
    events = trace_events()
    end_us = st.now_us() if st is not None else 0.0
    pid = os.getpid()
    snapshot = _metrics.metrics_snapshot()
    for mname, series in sorted(snapshot.items()):
        for labels, value in sorted(series.items()):
            if isinstance(value, dict):          # histogram stats
                value = value.get("sum", 0.0)
            disp = f"{mname}{{{labels}}}" if labels else mname
            events.append({"name": disp, "ph": "C", "ts": end_us,
                           "pid": pid, "tid": 0,
                           "args": {"value": value}})
    payload = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "repro_metrics": snapshot,
        "repro_decisions": [r.to_dict() for r in _decisions.decision_log()],
        "otherData": {"walltime": _walltime()},
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, default=_jsonable)
    return path


def stop_tracing(path: Optional[str] = None) -> Optional[str]:
    """End the session; write the trace to ``path`` (or the path given
    at start) if any.  The decision log survives the stop so
    ``check_drift`` can run against it later.  Returns the written path."""
    global _STATE
    st = _STATE
    if st is None:
        return None
    out = path or st.path
    written = export_trace(out) if out else None
    from repro.obs import metrics as _metrics
    _metrics._remove_pallas_probe()
    _STATE = None
    return written


class _Tracing:
    """``with tracing(path):`` — start on enter, write + stop on exit."""

    def __init__(self, path=None):
        self._path = path

    def __enter__(self):
        start_tracing(self._path)
        return self

    def __exit__(self, *exc):
        stop_tracing()
        return False


def tracing(path: Optional[str] = None) -> "_Tracing":
    """Context manager enabling the obs layer for its body; exports the
    Chrome-trace JSON to ``path`` on exit when one is given."""
    return _Tracing(path)


def _env_autostart() -> None:
    """``REPRO_TRACE=trace.json`` starts a process-lifetime session whose
    trace is written at interpreter exit (called once from
    ``repro.obs.__init__``)."""
    path = os.environ.get("REPRO_TRACE")
    if not path or _STATE is not None:
        return
    import atexit
    start_tracing(path)
    atexit.register(stop_tracing)
