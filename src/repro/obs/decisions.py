"""Structured decision log + input-drift advisories.

Every config pick — ``CostModel.best``, ``SpMMDecider.predict``,
``oracle_search`` — records *why* it chose its ⟨W,F,V,S,B⟩: the input
feature snapshot it decided on, the top-k priced/measured candidates,
the chosen config, and the calibration artifact id.  Records live on a
process-wide log (exported under ``repro_decisions`` in the trace JSON)
and survive ``stop_tracing`` so ``check_drift(csr)`` can later compare a
record's snapshot against the graph's *current* stats: when any tracked
feature moved by more than ``DRIFT_THRESHOLD`` relative, it returns a
``DriftAdvisory`` recommending re-selection — the observable half of the
ROADMAP "decider re-selection on input drift" item.

Core modules (``repro.core.*``) are imported lazily inside functions
only: ``pcsr.py``/``cost_model.py`` import this package for their own
instrumentation, so a module-level import would be circular.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from time import time as _walltime
from typing import Any, Optional

from repro.obs import metrics as _metrics, trace as _trace

__all__ = [
    "DecisionRecord", "DriftAdvisory", "DRIFT_FEATURES", "DRIFT_THRESHOLD",
    "record_decision", "decision_log", "clear_decisions",
    "graph_snapshot", "check_drift", "resolve_drift_thresholds",
]

_LOCK = threading.Lock()
_LOG: list["DecisionRecord"] = []

#: Snapshot features compared by ``check_drift`` (names match
#: ``repro.core.features.FEATURE_NAMES`` so decider feature dicts are
#: drop-in snapshots).
DRIFT_FEATURES = ("n", "nnz", "d", "d_max", "cv", "rho", "pr_2")

#: Default relative change in a ``DRIFT_FEATURES`` entry that trips an
#: advisory.  Per-feature overrides: pass ``check_drift`` a
#: ``{feature: threshold}`` dict, or set ``REPRO_DRIFT_THRESHOLD`` to a
#: scalar (``"0.1"``) or a comma list (``"nnz=0.1,cv=0.5"``).
DRIFT_THRESHOLD = 0.25

#: Environment hook consulted when ``check_drift`` is called without an
#: explicit threshold.
DRIFT_THRESHOLD_ENV = "REPRO_DRIFT_THRESHOLD"


def resolve_drift_thresholds(threshold=None) -> dict:
    """Normalize a threshold spec into a full ``{feature: float}`` map.

    ``threshold`` may be a scalar (applied to every feature), a partial
    ``{feature: float}`` dict (unlisted features keep ``DRIFT_THRESHOLD``),
    or ``None`` — which consults ``$REPRO_DRIFT_THRESHOLD``: either a
    scalar float string or a comma-separated ``feature=value`` list,
    falling back to ``DRIFT_THRESHOLD`` when unset.  Unknown feature
    names raise (a typo'd override silently never firing is worse than
    an error).
    """
    if threshold is None:
        import os
        spec = os.environ.get(DRIFT_THRESHOLD_ENV, "").strip()
        if not spec:
            threshold = DRIFT_THRESHOLD
        elif "=" in spec:
            threshold = {}
            for item in spec.split(","):
                name, _, val = item.partition("=")
                threshold[name.strip()] = float(val)
        else:
            threshold = float(spec)
    if isinstance(threshold, dict):
        unknown = set(threshold) - set(DRIFT_FEATURES)
        if unknown:
            raise ValueError(f"unknown drift feature(s) {sorted(unknown)} "
                             f"— valid: {DRIFT_FEATURES}")
        return {name: float(threshold.get(name, DRIFT_THRESHOLD))
                for name in DRIFT_FEATURES}
    return {name: float(threshold) for name in DRIFT_FEATURES}


@dataclass
class DecisionRecord:
    """One config pick: who decided, on what input, among which
    candidates, priced by which calibration artifact."""

    source: str                     # "cost_model" | "decider" | "oracle_*"
    op: str
    dim: int
    heads: int
    chosen: tuple                   # ⟨W,F,V,S,B⟩ via SpMMConfig.astuple()
    predicted_seconds: Optional[float]
    topk: list                      # [{"config": [...], "seconds"|"score"}]
    snapshot: dict                  # input features the pick was based on
    calibration: Optional[str]      # artifact id, None = analytic prices
    walltime: float = field(default_factory=_walltime)

    def to_dict(self) -> dict:
        return {
            "source": self.source, "op": self.op, "dim": self.dim,
            "heads": self.heads, "chosen": list(self.chosen),
            "predicted_seconds": self.predicted_seconds,
            "topk": self.topk, "snapshot": self.snapshot,
            "calibration": self.calibration, "walltime": self.walltime,
        }


@dataclass
class DriftAdvisory:
    """``check_drift`` verdict: which snapshot features moved, by how
    much, and the decision they invalidate."""

    drifted: dict                   # feature -> {recorded, current, rel}
    record: DecisionRecord
    message: str


def _cfg_tuple(config) -> tuple:
    """⟨W,F,V,S,B⟩ from an SpMMConfig (or pass tuples through)."""
    astuple = getattr(config, "astuple", None)
    return tuple(astuple()) if astuple is not None else tuple(config)


def _calibration_id(calibration) -> Optional[str]:
    """Stable id for the pricing artifact: fitted ops @ host, or None
    for the hand-set analytic constants."""
    if calibration is None:
        return None
    if isinstance(calibration, (str, bytes)):        # a path to the artifact
        import os
        return os.path.basename(os.fspath(calibration))
    meta = getattr(calibration, "meta", None) or {}
    coef = getattr(calibration, "coef", None) or {}
    ops = "+".join(sorted(coef)) or "uncalibrated"
    return f"{ops}@{meta.get('host', 'unknown-host')}"


def graph_snapshot(csr) -> dict:
    """Cheap ``DRIFT_FEATURES`` snapshot of a CSR matrix — degree stats
    plus the V=2 padding ratio from ``pcsr_stats`` (the layout-facing
    stat re-packing decisions hinge on).  Much cheaper than
    ``extract_features`` (no split/balance searches)."""
    import numpy as np

    from repro.core.pcsr import pcsr_stats

    n, nnz = csr.n_rows, csr.nnz
    deg = csr.degrees.astype(np.float64)
    d = nnz / max(1, n)
    st2 = pcsr_stats(csr.indptr, csr.indices, n, csr.n_cols, 2, 4)
    return {
        "n": float(n), "nnz": float(nnz), "d": d,
        "d_max": float(deg.max()) if n else 0.0,
        "cv": float(deg.std() / d) if d > 0 else 0.0,
        "rho": nnz / max(1, n * csr.n_cols),
        "pr_2": float(st2.padding_ratio),
    }


def record_decision(csr=None, *, source: str, dim: int, chosen,
                    op: str = "spmm", heads: int = 1,
                    predicted_seconds: Optional[float] = None,
                    candidates=None, scores=None, calibration=None,
                    snapshot: Optional[dict] = None,
                    k: int = 5) -> Optional[DecisionRecord]:
    """Append one pick to the decision log (no-op → ``None`` while
    tracing is disabled).  ``candidates`` is an iterable of
    ``(config, seconds)`` pairs — the top-``k`` cheapest are kept;
    ``scores`` is the higher-is-better alternative (the decider's class
    probabilities) kept as the top-``k`` highest.  ``snapshot``
    overrides the ``graph_snapshot(csr)`` default (the decider passes
    its full Table-3 feature dict)."""
    if not _trace.trace_enabled():
        return None
    if snapshot is None:
        snapshot = graph_snapshot(csr) if csr is not None else {}
    topk = []
    if candidates is not None:
        ranked = sorted(((_cfg_tuple(c), float(t)) for c, t in candidates),
                        key=lambda ct: ct[1])[:k]
        topk = [{"config": list(c), "seconds": t} for c, t in ranked]
    elif scores is not None:
        ranked = sorted(((_cfg_tuple(c), float(s)) for c, s in scores),
                        key=lambda cs: -cs[1])[:k]
        topk = [{"config": list(c), "score": s} for c, s in ranked]
    rec = DecisionRecord(
        source=source, op=op, dim=int(dim), heads=int(heads),
        chosen=_cfg_tuple(chosen),
        predicted_seconds=(None if predicted_seconds is None
                           else float(predicted_seconds)),
        topk=topk, snapshot=dict(snapshot),
        calibration=_calibration_id(calibration))
    with _LOCK:
        _LOG.append(rec)
    _metrics.counter("decisions_total").inc(source=source, op=op)
    _trace.instant("decision", cat="decision", source=source, op=op,
                   dim=rec.dim, chosen=list(rec.chosen))
    return rec


def decision_log() -> list[DecisionRecord]:
    """Snapshot of the decision log (survives ``stop_tracing``; cleared
    on the next ``start_tracing`` or by ``clear_decisions``)."""
    with _LOCK:
        return list(_LOG)


def clear_decisions() -> None:
    with _LOCK:
        _LOG.clear()


def check_drift(csr, record: Optional[DecisionRecord] = None, *,
                threshold=None) -> Optional[DriftAdvisory]:
    """Compare ``csr``'s current stats against the feature snapshot a
    decision was made on (default: the most recent logged record).
    Returns a ``DriftAdvisory`` when any ``DRIFT_FEATURES`` entry moved
    by more than its threshold relative — the signal to re-run config
    selection / re-pack — else ``None``.  ``threshold`` accepts a
    scalar, a per-feature dict, or ``None`` (the
    ``$REPRO_DRIFT_THRESHOLD`` env hook / ``DRIFT_THRESHOLD`` default —
    see ``resolve_drift_thresholds``); each drifted entry records the
    threshold that fired it.  Pure comparison: works whether or not
    tracing is currently enabled (the advisory counter/event only fire
    when it is)."""
    if record is None:
        log = decision_log()
        if not log:
            raise ValueError("no decision recorded — nothing to check "
                             "drift against")
        record = log[-1]
    thresholds = resolve_drift_thresholds(threshold)
    current = graph_snapshot(csr)
    drifted = {}
    for name in DRIFT_FEATURES:
        if name not in record.snapshot:
            continue
        old, new = float(record.snapshot[name]), float(current[name])
        rel = abs(new - old) / max(abs(old), 1e-12)
        if rel > thresholds[name]:
            drifted[name] = {"recorded": old, "current": new, "rel": rel,
                             "threshold": thresholds[name]}
    if not drifted:
        return None
    moved = ", ".join(f"{k} {v['recorded']:.3g}→{v['current']:.3g} "
                      f"({v['rel']:+.0%} > {v['threshold']:.0%})"
                      for k, v in drifted.items())
    msg = (f"input drifted since the {record.source} pick of "
           f"{record.chosen} (op={record.op}, dim={record.dim}): {moved} "
           f"— re-run config selection / re-pack")
    _metrics.counter("drift_advisories_total").inc(source=record.source)
    _trace.instant("drift_advisory", cat="decision",
                   features=sorted(drifted), source=record.source)
    return DriftAdvisory(drifted=drifted, record=record, message=msg)
