"""SpMM-decider training harness (paper §5-6.3).

Labels come from the oracle search over the ⟨W,F,V,S⟩ space: cost-model
pricing at corpus scale (the TPU kernel is the deployment target — CPU
wall-time can't see F), plus a measured-mode evaluation on a subset for
validation.  Train/test split is BY GRAPH to avoid leakage (the paper's
80/20 split of matrices).

``--op {spmm,sddmm,gat}`` selects the operator the labels are priced
for: the cost model's per-operator support (``CostModel.time(op=...)``)
means one harness trains a per-operator decider — e.g. ``--op gat``
labels each (graph, dim) with the config minimizing the fused
SDDMM+softmax pass *plus* the SpMM aggregation pass.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.autotune import oracle_search
from repro.core.cost_model import CostModel
from repro.core.decider import RandomForest, SpMMDecider
from repro.core.features import extract_features
from repro.core.pcsr import SpMMConfig, config_space
from repro.data.graphs import corpus
from repro.obs import span, tracing

DIMS = tuple(range(16, 257, 16))           # the paper's dim sweep


@dataclass
class DeciderDataset:
    samples: list                          # (features, dim, best_cfg)
    times: dict                            # (gname, dim) -> {cfg: time}
    graph_names: list
    by_graph: dict                         # gname -> [sample indices]
    op: str = "spmm"                       # operator the labels price


def build_dataset(graphs=None, dims=DIMS, mode: str = "model",
                  op: str = "spmm", H: int = 1, calibration=None,
                  verbose=False) -> DeciderDataset:
    """``H`` is the head count the oracle labels are collected for —
    multi-head GAT deciders must be trained on ``H``-aware labels (the
    optimal F/V/S shifts with the per-head dim), not the H=1 ones.

    ``calibration`` (a ``CalibrationResult`` or artifact path) makes the
    model-mode labels come from the *fitted* cost model — the decider
    then learns the config ranking this host measurably exhibits instead
    of the hand-set napkin-math one.  Ignored in measured mode."""
    graphs = graphs if graphs is not None else corpus("bench")
    if calibration is not None and not hasattr(calibration, "price"):
        from repro.core.calibrate import CalibrationResult
        calibration = CalibrationResult.load(calibration)
    samples, times, by_graph = [], {}, {}
    for g in graphs:
        t0 = time.time()
        with span("decider.label_graph", graph=g.name, mode=mode, op=op):
            feats = extract_features(g.csr)
            cm = (CostModel(g.csr, calibration=calibration)
                  if mode == "model" else None)
            for dim in dims:
                res = oracle_search(g.csr, dim, mode=mode, cm=cm, op=op,
                                    H=H)
                samples.append((feats, dim, res.best_config))
                times[(g.name, dim)] = res.times
                by_graph.setdefault(g.name, []).append(len(samples) - 1)
        if verbose:
            print(f"  {g.name}: {time.time()-t0:.1f}s")
    return DeciderDataset(samples, times, [g.name for g in graphs],
                          by_graph, op)


@dataclass
class DeciderEval:
    per_dim: dict                          # dim -> (pred_norm, rnd_norm)
    overall_pred: float
    overall_rnd: float
    decider: SpMMDecider
    # decider-vs-oracle quality on the held-out graphs: how often the
    # predicted config matches the oracle-best time (price ties count),
    # and the time ratio paid when it does not (regret = t_pred/t_best ≥ 1)
    per_dim_quality: dict = field(default_factory=dict)
    #   dim -> {"agreement": .., "mean_regret": ..}
    agreement: float = 0.0
    mean_regret: float = 1.0
    max_regret: float = 1.0


def train_eval(ds: DeciderDataset, *, test_frac=0.2, seed=0,
               n_estimators=60) -> DeciderEval:
    rng = np.random.default_rng(seed)
    names = list(ds.graph_names)
    rng.shuffle(names)
    n_test = max(1, int(len(names) * test_frac))
    test_names = set(names[:n_test])
    train_idx = [i for n in names[n_test:] for i in ds.by_graph[n]]
    test_idx = [i for n in test_names for i in ds.by_graph[n]]

    decider = SpMMDecider(
        forest=RandomForest(n_estimators=n_estimators, seed=seed))
    decider.fit([ds.samples[i] for i in train_idx])

    per_dim: dict = {}
    key_of = {}
    for n in ds.graph_names:
        for i in ds.by_graph[n]:
            key_of[i] = n
    for i in test_idx:
        feats, dim, best = ds.samples[i]
        tt = ds.times[(key_of[i], dim)]
        t_best = tt[best]
        pred = decider.predict(feats, dim)
        t_pred = tt.get(pred, max(tt.values()))
        rnd_cfg = list(tt)[int(rng.integers(len(tt)))]
        e = per_dim.setdefault(dim, [[], [], [], []])
        e[0].append(t_best / t_pred)       # normalized perf (throughput)
        e[1].append(t_best / tt[rnd_cfg])
        # agreement up to price ties: several configs often price
        # identically, so the oracle's exact tuple is arbitrary — what
        # matters is whether the pick costs what the best one costs
        e[2].append(1.0 if t_pred <= t_best * 1.001 else 0.0)
        e[3].append(t_pred / max(t_best, 1e-300))      # regret ≥ 1
    agg = {d: (float(np.mean(v[0])), float(np.mean(v[1])))
           for d, v in sorted(per_dim.items())}
    quality = {d: {"agreement": float(np.mean(v[2])),
                   "mean_regret": float(np.mean(v[3]))}
               for d, v in sorted(per_dim.items())}
    allp = [x for v in per_dim.values() for x in v[0]]
    allr = [x for v in per_dim.values() for x in v[1]]
    alla = [x for v in per_dim.values() for x in v[2]]
    allg = [x for v in per_dim.values() for x in v[3]]
    return DeciderEval(agg, float(np.mean(allp)), float(np.mean(allr)),
                       decider, per_dim_quality=quality,
                       agreement=float(np.mean(alla)),
                       mean_regret=float(np.mean(allg)),
                       max_regret=float(np.max(allg)))


def main(argv=None):
    ap = argparse.ArgumentParser(description="Train + evaluate the "
                                 "⟨W,F,V,S⟩ decider")
    ap.add_argument("--op", default="spmm",
                    choices=["spmm", "sddmm", "gat"],
                    help="operator the oracle labels are collected for")
    ap.add_argument("--mode", default="model",
                    choices=["model", "measured"],
                    help="label source: cost-model pricing or host timing")
    ap.add_argument("--heads", type=int, default=1,
                    help="head count the oracle labels are collected for "
                    "(multi-head GAT deciders need H-aware labels)")
    ap.add_argument("--scale", default="small",
                    choices=["small", "bench", "skewed", "large"],
                    help="graph corpus")
    ap.add_argument("--dims", default=None,
                    help="comma-separated embedding dims (default: paper "
                    "sweep 16..256)")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="calibration artifact (repro.core.calibrate "
                    "JSON): model-mode labels come from the fitted cost "
                    "model instead of the hand-set constants")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None,
                    help="pickle the trained decider to this path")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the labeling + "
                    "training run (per-graph spans, oracle decision log)")
    args = ap.parse_args(argv)

    import contextlib
    ctx = tracing(args.trace) if args.trace else contextlib.nullcontext()
    dims = (tuple(int(d) for d in args.dims.split(","))
            if args.dims else DIMS)
    with ctx:
        ds = build_dataset(corpus(args.scale), dims=dims, mode=args.mode,
                           op=args.op, H=args.heads,
                           calibration=args.calibration, verbose=True)
        with span("decider.train_eval", n_samples=len(ds.samples)):
            ev = train_eval(ds, seed=args.seed)
    if args.trace:
        print(f"trace written to {args.trace}")
    print(f"op={args.op} mode={args.mode} H={args.heads} "
          f"calibrated={args.calibration is not None} "
          f"graphs={len(ds.graph_names)}")
    for d, (pred, rnd) in ev.per_dim.items():
        q = ev.per_dim_quality[d]
        print(f"  dim={d:4d}  pred_norm={pred:.3f}  random_norm={rnd:.3f}"
              f"  agreement={q['agreement']:.2f}"
              f"  regret={q['mean_regret']:.3f}")
    print(f"overall: pred={ev.overall_pred:.3f} random={ev.overall_rnd:.3f} "
          f"agreement={ev.agreement:.3f} mean_regret={ev.mean_regret:.3f} "
          f"max_regret={ev.max_regret:.3f}")
    if args.save:
        ev.decider.save(args.save)
        print(f"saved decider to {args.save}")


if __name__ == "__main__":
    main()
