"""GNN training application (paper §6.5): GCN/GIN/GAT on a
node-classification task with ParamSpMM (or a baseline SpMM) as the
aggregation operator.  GAT aggregates through the fused
SDDMM→softmax→SpMM message function over the same PCSR.

``--partitions N`` (or ``train_gnn(partitions=N)``) swaps the
single-device operator for the distributed one (``repro.dist``): the
graph is row-partitioned over an N-device mesh and every shard runs its
own cost-model-selected ⟨W,F,V,S⟩ configuration — priced per head count
for GAT (``--heads`` works distributed: every head batches through one
head-tiled SPMD program).  ``--overlap`` turns on the halo/compute
overlap decomposition for the SpMM aggregations (see
docs/DISTRIBUTED.md).

``--mutate N`` appends a streaming-mutation demo after training: N
random insert/delete churn batches against the trained graph's
normalized adjacency through a self-healing ``repro.dynamic``
``DynamicGraph``, printing every governor verdict and verifying the
final aggregation is exact against a from-scratch re-pack (see
docs/DYNAMIC.md)."""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import make_cusparse_analog, make_gespmm_analog
from repro.core.pcsr import SpMMConfig
from repro.data.tasks import NodeTask
from repro.models.gnn import (accuracy, gat_forward, gcn_forward,
                              gin_forward, init_gat, init_gcn, init_gin,
                              node_ce_loss)
from repro.obs import instant, span, tracing
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.pipeline import ParamSpMM


@dataclass
class GNNTrainResult:
    losses: list = field(default_factory=list)
    val_acc: float = 0.0
    seconds_per_step: float = 0.0
    config: SpMMConfig | list | None = None   # list = per-partition configs


def build_spmm(task: NodeTask, dim: int, mode: str = "paramspmm", *,
               partitions: int = 0, partition_strategy: str = "balanced",
               **kw):
    """SpMM closure over Â (GCN-normalized adjacency). Returns (fn, perm,
    config).  ``partitions > 0`` builds the distributed operator instead
    (no reorder — node ids must stay aligned with the partition map);
    config is then the per-shard list."""
    csr = task.csr.gcn_normalize()
    if partitions:
        if mode != "paramspmm":
            raise ValueError("partitioned execution needs mode='paramspmm'")
        from repro.dist import DistGraph
        g = DistGraph(csr, dim, partitions, strategy=partition_strategy, **kw)
        return g, None, g.configs
    if mode == "paramspmm":
        p = ParamSpMM(csr, dim, **kw)
        return p, p.perm, p.config
    if mode == "cusparse":
        return make_cusparse_analog(csr), None, None
    if mode == "gespmm":
        return make_gespmm_analog(csr), None, None
    raise ValueError(mode)


def train_gnn(task: NodeTask, *, model: str = "gcn", hidden: int = 64,
              n_layers: int = 5, steps: int = 100, lr: float = 5e-3,
              spmm_mode: str = "paramspmm", seed: int = 0, heads: int = 1,
              partitions: int = 0, partition_strategy: str = "balanced",
              overlap: bool = False, fused: bool = True,
              spmm_kwargs: dict | None = None) -> GNNTrainResult:
    """``fused=True`` (default) lets GCN layers hand bias + ReLU to the
    SpMM's fused epilogue (one kernel per aggregation on the Pallas
    backend); ``fused=False`` keeps the classic ``spmm(h) @ W + b`` order
    — bit-identical to the baseline backends, which never fuse."""
    kw = dict(spmm_kwargs or {})
    if partitions and overlap and model != "gat":
        # GAT's attention chain never takes the overlap path (see
        # DistGraph) — don't build the unused local/halo decomposition
        kw.setdefault("overlap", True)
    if model == "gat":
        if spmm_mode != "paramspmm":
            raise ValueError("gat needs the PCSR message fn "
                             "(spmm_mode='paramspmm')")
        # pick the config for the SDDMM+SpMM pair, not the SpMM alone —
        # priced per head count (head tiling changes the optimal F);
        # DistGraph takes the same op/heads kwargs for per-shard selection
        kw.setdefault("op", "gat")
        kw.setdefault("heads", heads)
        if not partitions:
            # engine backward is native autodiff; the Pallas backward runs
            # its dK/dVf SpMMs on the operator's cached transpose PCSR
            kw.setdefault("build_transpose",
                          kw.get("backend", "engine") == "pallas")
    with span("gnn.pack", model=model, mode=spmm_mode,
              partitions=partitions):
        spmm, perm, cfg = build_spmm(task, hidden, spmm_mode,
                                     partitions=partitions,
                                     partition_strategy=partition_strategy,
                                     **kw)
    if not fused and model != "gat" and hasattr(spmm, "fused"):
        op = spmm                 # hide the fusion surface: plain closure
        spmm = lambda B: op(B)    # → gcn/gin take the unfused branch
    X = jnp.asarray(task.features)
    labels = jnp.asarray(task.labels)
    tmask = jnp.asarray(task.train_mask)
    vmask = jnp.asarray(task.val_mask)
    if perm is not None:   # graph was reordered → permute node-aligned data
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        X, labels = X[jnp.asarray(inv)], labels[jnp.asarray(inv)]
        tmask, vmask = tmask[jnp.asarray(inv)], vmask[jnp.asarray(inv)]

    feat_dim = X.shape[1]
    dims = [feat_dim] + [hidden] * (n_layers - 1) + [task.n_classes]
    key = jax.random.PRNGKey(seed)
    if model == "gcn":
        params = init_gcn(key, dims)
        fwd = gcn_forward
    elif model == "gin":
        params = init_gin(key, dims)
        fwd = gin_forward
    elif model == "gat":
        import functools

        from repro.core.engine import make_gat_message_fn
        params = init_gat(key, dims, heads=heads)
        fwd = functools.partial(gat_forward, heads=heads)
        if partitions:
            # DistGraph's sharded message fn: single-head (n, d) or
            # multi-head (H, n, d) stacks, one SPMD program either way
            spmm = spmm.gat_message
        else:
            # the message fn aggregates instead of the plain-SpMM closure,
            # over the very same PCSR (+ transpose PCSR) the pipeline built
            spmm = make_gat_message_fn(spmm.op.pcsr, spmm.op.pcsr_t,
                                       backend=kw.get("backend", "engine"),
                                       interpret=kw.get("interpret", True))
    else:
        raise ValueError(model)

    opt_cfg = AdamWConfig(lr=lr)
    opt = adamw_init(params)

    def loss_fn(p):
        logits = fwd(p, X, spmm)
        return node_ce_loss(logits, labels, tmask)

    grad_fn = jax.value_and_grad(loss_fn)

    res = GNNTrainResult(config=cfg)
    t0 = None
    for step in range(steps):
        # step 0 pays tracing + compilation — its span is named apart so
        # the trace separates warmup from steady-state steps
        with span("gnn.compile" if step == 0 else "gnn.step", step=step):
            loss, grads = grad_fn(params)
            params, opt = adamw_update(params, grads, opt, opt_cfg)
            if step == 0:
                jax.block_until_ready(loss)
        res.losses.append(float(loss))
        if step == 0:      # exclude jit warmup from timing
            t0 = time.perf_counter()
    jax.block_until_ready(params)
    if steps > 1:
        res.seconds_per_step = (time.perf_counter() - t0) / (steps - 1)
        instant("gnn.steady_state", seconds_per_step=res.seconds_per_step)
    with span("gnn.eval"):
        logits = fwd(params, X, spmm)
        res.val_acc = float(accuracy(logits, labels, vmask))
    return res


def run_mutation_stream(csr, dim: int, batches: int, *, seed: int = 0,
                        inserts: int = 150, deletes: int = 130,
                        slack: float = 1.1, amortize_steps: int = 20):
    """Churn ``csr`` through a self-healing ``DynamicGraph`` and report
    each governor verdict; ends with an exactness check of the degraded
    aggregation against a from-scratch re-pack of the mutated edges."""
    from repro.core.engine import make_spmm_fn
    from repro.core.pcsr import build_pcsr
    from repro.dynamic import DynamicGraph

    rng = np.random.default_rng(seed)
    g = DynamicGraph(csr, dim, slack=slack, amortize_steps=amortize_steps)
    X = jnp.asarray(rng.standard_normal((csr.n_cols, dim)), jnp.float32)
    for step in range(batches):
        r, c = rng.integers(0, csr.n_rows, (2, inserts))
        g.insert_edges(r, c,
                       rng.uniform(0.5, 1.5, inserts).astype(np.float32))
        m = g.dyn.to_csr()
        rows = np.repeat(np.arange(m.n_rows), np.diff(m.indptr))
        pick = rng.permutation(m.nnz)[:deletes]
        _, dec = g.delete_edges(rows[pick], m.indices[pick])
        instant("gnn.mutate", step=step, action=dec.action)
        print(f"mutate[{step}]: nnz={g.dyn.nnz} chunks={g.dyn.num_chunks} "
              f"slot_fill={g.dyn.slot_fill:.2f} -> {dec.action} "
              f"({dec.reason})")
    out = np.asarray(g.spmm(X))
    m = g.dyn.to_csr()
    fresh = build_pcsr(m.indptr, m.indices, m.data, m.n_rows, m.n_cols,
                       g.config)
    err = float(np.abs(out - np.asarray(make_spmm_fn(fresh)(X))).max())
    n_repack = sum(d.action == "repack" for d in g.decisions)
    print(f"mutate: aggregation matches a fresh re-pack "
          f"(max |Δ| = {err:.2e}, summation-order noise only); "
          f"repacks={n_repack}")
    return g


def main(argv=None):
    from repro.data.tasks import community_task

    ap = argparse.ArgumentParser(description="GNN training on a synthetic "
                                 "node-classification task")
    ap.add_argument("--model", default="gcn", choices=["gcn", "gin", "gat"])
    ap.add_argument("--partitions", type=int, default=0,
                    help="row-partition the graph over N mesh devices "
                    "(0 = single-device)")
    ap.add_argument("--partition-strategy", default="balanced",
                    choices=["contiguous", "balanced"])
    ap.add_argument("--overlap", action="store_true",
                    help="hide the halo all_gather behind the shard-local "
                    "SpMM (DistGraph(overlap=True); needs --partitions)")
    ap.add_argument("--spmm", default="paramspmm",
                    choices=["paramspmm", "cusparse", "gespmm"])
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--heads", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mutate", type=int, default=0, metavar="N",
                    help="after training, stream N random insert/delete "
                    "churn batches through a self-healing DynamicGraph "
                    "on the trained adjacency (repro.dynamic demo)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the run (read it "
                    "with repro.apps.obs_report or Perfetto)")
    args = ap.parse_args(argv)

    import contextlib
    ctx = tracing(args.trace) if args.trace else contextlib.nullcontext()
    with ctx:
        task = community_task(seed=args.seed)
        res = train_gnn(task, model=args.model, hidden=args.hidden,
                        n_layers=args.layers, steps=args.steps,
                        spmm_mode=args.spmm, heads=args.heads,
                        seed=args.seed, partitions=args.partitions,
                        partition_strategy=args.partition_strategy,
                        overlap=args.overlap)
        if args.mutate:
            run_mutation_stream(task.csr.gcn_normalize(), args.hidden,
                                args.mutate, seed=args.seed)
    if args.trace:
        print(f"trace written to {args.trace}")
    print(f"val_acc={res.val_acc:.3f} "
          f"ms_per_step={res.seconds_per_step * 1e3:.1f}")
    cfgs = res.config if isinstance(res.config, list) else [res.config]
    for i, c in enumerate(cfgs):
        if c is not None:
            w, f, v, s, b = c.astuple()
            print(f"partition {i}: W={w} F={f} V={v} S={s} B={b}")


if __name__ == "__main__":
    main()
