"""GNN inference serving driver — seeded stream replay through
``repro.serve`` (docs/SERVING.md).

    PYTHONPATH=src python -m repro.apps.serve_gnn \\
        --graph rmat13 --model gcn --requests 32 --check

Replays a seeded bursty synthetic request stream through ``GNNService``
and prints per-bucket traffic, cache hit/miss, and latency percentiles.
``--check`` re-runs every request through the full-pipeline reference
forward (same subgraph, same config, no bucketing) and asserts the
served outputs match.  ``--stats PATH`` writes the summary JSON the CI
smoke asserts on; ``--trace PATH`` wraps the run in ``repro.obs``
tracing (serve spans + counters exported as Chrome-trace JSON).
"""
from __future__ import annotations

import argparse
import contextlib
import json

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--graph", default="rmat13",
                    help="corpus('serve') graph name")
    ap.add_argument("--model", default="gcn",
                    choices=["gcn", "gin", "gat"])
    ap.add_argument("--backend", default="engine",
                    choices=["engine", "pallas"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--tick-every", type=int, default=8)
    ap.add_argument("--cache-capacity", type=int, default=8)
    ap.add_argument("--check", action="store_true",
                    help="assert served outputs match the full-pipeline "
                    "reference forward on every request")
    ap.add_argument("--stats", default=None, metavar="PATH",
                    help="write summary JSON")
    ap.add_argument("--trace", nargs="?", const="serve_trace.json",
                    default=None, metavar="PATH")
    args = ap.parse_args(argv)

    import jax
    from repro.data.graphs import corpus
    from repro.models.gnn import init_gat, init_gcn, init_gin
    from repro.obs import metrics_snapshot, tracing
    from repro.serve import (GNNService, reference_forward, replay,
                             synthetic_stream)

    specs = {s.name: s for s in corpus("serve")}
    if args.graph not in specs:
        ap.error(f"--graph must be one of {sorted(specs)}")
    g = specs[args.graph].csr
    if args.model != "gat":
        g = g.gcn_normalize()

    rng = np.random.default_rng(args.seed)
    feats = rng.integers(0, 4, (g.n_rows, args.feat)).astype(np.float32)
    key = jax.random.PRNGKey(args.seed)
    dims = [args.feat, args.hidden, args.classes]
    init = {"gcn": init_gcn, "gin": init_gin, "gat": init_gat}[args.model]
    params = init(key, dims)

    stream = synthetic_stream(args.requests, g.n_rows, seed=args.seed)
    ctx = tracing(args.trace) if args.trace else contextlib.nullcontext()
    with ctx:
        svc = GNNService(g, feats, params, model=args.model,
                         backend=args.backend,
                         cache_capacity=args.cache_capacity,
                         keep_subgraphs=args.check)
        results = replay(svc, stream, tick_every=args.tick_every)
        snap = {k: v for k, v in metrics_snapshot().items()
                if k.startswith("serve_")}

    assert len(results) == args.requests
    lat = np.array([r.latency_s for r in results]) * 1e3
    cache = svc.cache
    per_bucket: dict = {}
    for r in results:
        per_bucket[r.bucket_key] = per_bucket.get(r.bucket_key, 0) + 1

    checked = 0
    if args.check:
        for r in results:
            sr = r.sampled
            ref = np.asarray(reference_forward(
                sr.sub, feats[sr.nodes], params, model=args.model,
                config=r.config, backend=args.backend))[sr.seed_local]
            np.testing.assert_allclose(r.outputs, ref, rtol=1e-5,
                                       atol=1e-5,
                                       err_msg=f"request {r.rid}")
            checked += 1
        assert cache.hits > 0, "no steering-pack cache hits on the stream"
        print(f"check: {checked}/{len(results)} requests match the "
              f"full-pipeline reference")

    stats = {
        "graph": args.graph, "model": args.model, "backend": args.backend,
        "requests": len(results), "batches": len(svc.batch_log),
        "buckets": per_bucket,
        "cache_hits": cache.hits, "cache_misses": cache.misses,
        "cache_evictions": cache.evictions,
        "cache_hit_rate": cache.hit_rate,
        "compiled_buckets": svc.compiled_buckets,
        "latency_ms_p50": float(np.percentile(lat, 50)),
        "latency_ms_p99": float(np.percentile(lat, 99)),
        "checked": checked,
    }
    if args.trace:
        stats["counters"] = snap
    print(f"served {stats['requests']} requests in {stats['batches']} "
          f"batches across {len(per_bucket)} buckets "
          f"({svc.compiled_buckets} compiled)")
    print(f"cache: {cache.hits} hits / {cache.misses} misses "
          f"(hit rate {cache.hit_rate:.2f})")
    print(f"latency p50 {stats['latency_ms_p50']:.1f} ms, "
          f"p99 {stats['latency_ms_p99']:.1f} ms")
    if args.stats:
        with open(args.stats, "w") as fh:
            json.dump(stats, fh, indent=2)
        print(f"# wrote {args.stats}")
    return stats


if __name__ == "__main__":
    main()
