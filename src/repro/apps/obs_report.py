"""Reader CLI for ``repro.obs`` Chrome-trace exports.

``python -m repro.apps.obs_report trace.json`` prints four sections:

* **span tree** — ``"X"`` complete events re-nested by ts/dur
  containment per (pid, tid), aggregated by path (count, total, self);
* **top-N self time** — spans ranked by exclusive time;
* **counters** — every metric series from the ``repro_metrics``
  snapshot (histograms show count/mean/min/max);
* **decisions** — the ``repro_decisions`` log: per-source counts plus
  the chosen config, predicted time, and runner-up candidates of each
  record.

The file is the plain Chrome trace event format, so the same trace also
loads in Perfetto / ``chrome://tracing`` (see docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def _build_tree(events):
    """Nest ``X`` events by containment per (pid, tid); aggregate nodes
    by path.  Returns {path_tuple: [count, total_us, self_us]}."""
    agg: dict = defaultdict(lambda: [0, 0.0, 0.0])
    by_thread: dict = defaultdict(list)
    for e in events:
        if e.get("ph") == "X":
            by_thread[(e.get("pid"), e.get("tid"))].append(e)
    for evs in by_thread.values():
        # children have later ts and earlier (or equal) end; sorting by
        # (ts, -dur) visits parents before their children
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: list = []            # [(end_us, path, node)]
        for e in evs:
            ts, dur = e["ts"], e["dur"]
            while stack and ts >= stack[-1][0] - 1e-9:
                stack.pop()
            path = (stack[-1][1] if stack else ()) + (e["name"],)
            node = agg[path]
            node[0] += 1
            node[1] += dur
            node[2] += dur
            if stack:
                stack[-1][2][2] -= dur      # parent's self time
            stack.append((ts + dur, path, node))
    return dict(agg)


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def _print_tree(agg, out):
    print("== span tree (count · total · self) ==", file=out)
    if not agg:
        print("  (no spans)", file=out)
        return
    for path in sorted(agg):        # parents sort before their children
        count, total, self_us = agg[path]
        indent = "  " * len(path)
        print(f"{indent}{path[-1]}  ×{count}  {_fmt_us(total)}  "
              f"(self {_fmt_us(self_us)})", file=out)


def _print_top_self(agg, n, out):
    by_name: dict = defaultdict(lambda: [0, 0.0])
    for path, (count, _total, self_us) in agg.items():
        by_name[path[-1]][0] += count
        by_name[path[-1]][1] += self_us
    print(f"\n== top {n} spans by self time ==", file=out)
    ranked = sorted(by_name.items(), key=lambda kv: -kv[1][1])[:n]
    if not ranked:
        print("  (no spans)", file=out)
    for name, (count, self_us) in ranked:
        print(f"  {_fmt_us(self_us):>10}  ×{count:<5} {name}", file=out)


def _print_counters(metrics, out):
    print("\n== counters / gauges / histograms ==", file=out)
    if not metrics:
        print("  (no metrics)", file=out)
        return
    for name in sorted(metrics):
        for labels, value in sorted(metrics[name].items()):
            series = f"{name}{{{labels}}}" if labels else name
            if isinstance(value, dict):
                mean = value["sum"] / max(1, value["count"])
                print(f"  {series}: count={value['count']} "
                      f"mean={mean:.3g}s min={value['min']:.3g}s "
                      f"max={value['max']:.3g}s", file=out)
            else:
                v = f"{value:g}" if isinstance(value, float) else value
                print(f"  {series}: {v}", file=out)


def _print_decisions(decisions, out, limit=10):
    print(f"\n== decisions ({len(decisions)} recorded) ==", file=out)
    by_source: dict = defaultdict(int)
    for d in decisions:
        by_source[d.get("source", "?")] += 1
    for src, n in sorted(by_source.items()):
        print(f"  {src}: {n}", file=out)
    for d in decisions[:limit]:
        t = d.get("predicted_seconds")
        t_s = f" pred={t * 1e6:.1f}us" if t is not None else ""
        cal = d.get("calibration")
        cal_s = f" cal={cal}" if cal else ""
        print(f"  - {d.get('source')} op={d.get('op')} dim={d.get('dim')} "
              f"H={d.get('heads')} → {tuple(d.get('chosen', ()))}"
              f"{t_s}{cal_s}", file=out)
        for c in d.get("topk", [])[1:3]:
            v = c.get("seconds")
            v_s = (f"{v * 1e6:.1f}us" if v is not None
                   else f"score={c.get('score'):.3f}")
            print(f"      runner-up {tuple(c['config'])}  {v_s}", file=out)
    if len(decisions) > limit:
        print(f"  … {len(decisions) - limit} more", file=out)


def report(payload: dict, top: int = 10, out=sys.stdout) -> None:
    events = payload.get("traceEvents", [])
    agg = _build_tree(events)
    _print_tree(agg, out)
    _print_top_self(agg, top, out)
    _print_counters(payload.get("repro_metrics", {}), out)
    _print_decisions(payload.get("repro_decisions", []), out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a repro.obs Chrome-trace JSON")
    ap.add_argument("trace", help="path to a trace written by "
                    "obs.tracing(path) / --trace / REPRO_TRACE")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the self-time ranking")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as fh:
            payload = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"cannot read trace {args.trace!r}: {e}", file=sys.stderr)
        return 1
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        print(f"{args.trace!r} is not a Chrome-trace export "
              "(no traceEvents key)", file=sys.stderr)
        return 1
    report(payload, top=args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())
