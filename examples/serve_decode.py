"""Serving example: batched prefill-free decode with KV/SSM caches on the
hybrid (hymba) architecture — exercises ring-buffer SWA caches, global
caches, and SSM state end to end.

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "hymba-1.5b", "--reduced", "--batch", "4",
          "--prompt-len", "12", "--gen", "24", "--temperature", "0.8"])
