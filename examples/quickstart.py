"""Quickstart: the ParamSpMM three-phase workflow (paper Fig. 2) on one
graph — features → config (cost-model oracle) → PCSR → SpMM, validated
against the oracle, on both the JAX engine and the Pallas TPU kernel
(interpret mode on CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.features import extract_features
from repro.data.graphs import clones, rmat
from repro.kernels.paramspmm import paramspmm, spmm_ref
from repro.pipeline import ParamSpMM

DIM = 64


def main():
    for name, graph in [("co-citation (local)", clones(4000, 10, seed=0)),
                        ("power-law (skewed)", rmat(11, 8, seed=0))]:
        feats = extract_features(graph).as_dict()
        sp = ParamSpMM(graph, DIM, reorder=True)
        print(f"\n=== {name}: n={graph.n_rows} nnz={graph.nnz} "
              f"cv={feats['cv']:.2f} pr2={feats['pr_2']:.3f}")
        print(f"  chosen ⟨W,F,V,S⟩ = {sp.config.astuple()}  "
              f"(PR_V={sp.op.pcsr.padding_ratio:.3f} "
              f"SR={sp.op.pcsr.split_ratio:.2f})")

        rng = np.random.default_rng(0)
        B = jnp.asarray(rng.standard_normal((graph.n_cols, DIM)),
                        jnp.float32)
        # note: pipeline reordered the graph; feed B in reordered space
        inv = np.argsort(sp.perm)
        Bp = B[jnp.asarray(inv)]
        out_engine = np.asarray(sp(Bp))

        out_kernel = np.asarray(paramspmm(sp.op.pcsr, Bp))
        ref = np.asarray(spmm_ref(sp.csr.indptr, sp.csr.indices,
                                  sp.csr.data, Bp, sp.csr.n_rows))
        print(f"  engine  max|err| = {np.abs(out_engine - ref).max():.2e}")
        print(f"  pallas  max|err| = {np.abs(out_kernel - ref).max():.2e}")


if __name__ == "__main__":
    main()
