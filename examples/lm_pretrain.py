"""End-to-end LM pretraining driver: a ~100M-parameter qwen2-family model
trained for a few hundred steps on the synthetic Markov stream, with
checkpointing — runnable on CPU (slowly) and unchanged on the production
mesh.

    PYTHONPATH=src python examples/lm_pretrain.py --steps 200
"""
import argparse

from repro.configs.qwen2_72b import CONFIG
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    # ~100M-param member of the qwen2 family (8L, d=768, ff=2048, 32k vocab)
    import repro.configs.qwen2_72b as q
    cfg100m = CONFIG.replace(n_layers=8, d_model=768, n_heads=12, n_kv=4,
                             head_dim=64, d_ff=2048, vocab=32000)
    q.REDUCED = cfg100m          # reuse the launcher's --reduced hook
    train(["--arch", "qwen2-72b", "--reduced",
           "--steps", str(args.steps), "--batch", "8", "--seq", "128",
           "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir, "--resume",
           "--ckpt-every", "50", "--log-every", "10"])


if __name__ == "__main__":
    main()
