"""End-to-end driver (paper §6.5): train GCN and GIN on a community
node-classification task with ParamSpMM aggregation, compare per-step
time against the vendor-library (BCOO) baseline, then train the
attention GNN (GAT) through the fused SDDMM→softmax→SpMM message path.

    PYTHONPATH=src python examples/gnn_training.py
"""
from repro.apps.gnn import train_gnn
from repro.data.tasks import community_task


def main():
    task = community_task(n_blocks=10, block_size=200, feat_dim=16,
                          p_in=0.1, noise=1.5, seed=0)
    print(f"graph: n={task.csr.n_rows} nnz={task.csr.nnz} "
          f"classes={task.n_classes}")
    for model in ("gcn", "gin"):
        ours = train_gnn(task, model=model, hidden=64, n_layers=5,
                         steps=60, spmm_mode="paramspmm")
        base = train_gnn(task, model=model, hidden=64, n_layers=5,
                         steps=60, spmm_mode="cusparse")
        print(f"{model.upper()}: ParamSpMM cfg={ours.config.astuple()} "
              f"loss {ours.losses[0]:.3f}→{ours.losses[-1]:.3f} "
              f"val_acc={ours.val_acc:.3f} "
              f"{ours.seconds_per_step*1e3:.1f} ms/step "
              f"(vendor baseline {base.seconds_per_step*1e3:.1f} ms/step, "
              f"acc {base.val_acc:.3f})")

    from repro.configs.gat import GAT_MH
    gat = train_gnn(task, model="gat", hidden=64, n_layers=3, steps=40,
                    spmm_mode="paramspmm", lr=5e-3, heads=GAT_MH["heads"])
    print(f"GAT({GAT_MH['heads']} heads): ParamSpMM cfg={gat.config.astuple()} "
          f"loss {gat.losses[0]:.3f}→{gat.losses[-1]:.3f} "
          f"val_acc={gat.val_acc:.3f} "
          f"{gat.seconds_per_step*1e3:.1f} ms/step "
          f"(fused SDDMM→softmax, then SpMM, per layer)")


if __name__ == "__main__":
    main()
